//! Ring-mode driver: the software producer/consumer for the DMAC's
//! memory-resident submission/completion rings (DESIGN.md §10).
//!
//! [`RingDriver`] owns one channel's ring pair: `submit_batch` writes
//! any number of descriptors into free submission-ring slots and
//! publishes them all with **one** doorbell write (the launch-path
//! amortization the rings exist for), and `poll_completions` consumes
//! completion-ring records by phase bit, frees the submission slots
//! they retire, and republishes the consumer index through the CQ
//! doorbell.  It can run pure-polling or be driven from the SoC's
//! coalesced ring IRQ ([`crate::soc::ring_irq_source`]).
//!
//! [`MultiRingDriver`] is the multi-tenant layer: per-client virtual
//! channels (pinned or deterministically least-loaded) multiplexed
//! onto the per-channel hardware rings, with globally monotone cookies
//! — the ring-mode analogue of [`super::MultiTenantDriver`].

use super::dmaengine::Cookie;
use super::multitenant::VchanId;
use super::retry::RetryPolicy;
use crate::dmac::config::RingParams;
use crate::dmac::descriptor::{NdExt, ND_EXT_BYTES};
use crate::dmac::ring::{CqRecord, CQ_RECORD_BYTES};
use crate::dmac::{Controller, Descriptor, DESC_BYTES};
use crate::sim::Cycle;
use crate::tb::System;
use crate::{Error, Result};
use std::collections::VecDeque;

/// One client transfer submitted through the ring.
#[derive(Debug, Clone, Copy)]
pub enum RingEntry {
    /// A linear copy: one 32-byte slot.
    Memcpy { dst: u64, src: u64, len: u32 },
    /// An ND-affine transfer: head word + extension word, two
    /// consecutive slots (wrapping at the top index like everything
    /// else).
    Nd { dst: u64, src: u64, row_bytes: u32, nd: NdExt },
}

impl RingEntry {
    fn slots(&self) -> u64 {
        match self {
            RingEntry::Memcpy { .. } => 1,
            RingEntry::Nd { .. } => 2,
        }
    }
}

/// A submitted batch entry awaiting its completion record.
#[derive(Debug, Clone, Copy)]
struct InFlight {
    cookie: Cookie,
    /// SQ slot of the head word (what the CQ record reports).
    head_slot: u32,
    /// Slots this entry occupies (freed when the record is consumed).
    slots: u64,
    done: bool,
    /// The original request, kept so an errored or halted entry can be
    /// rewritten into fresh slots and resubmitted.
    entry: RingEntry,
    /// Resubmissions so far (bounded by the driver's [`RetryPolicy`]).
    attempts: u32,
}

/// A retired entry whose CQ record carried a nonzero status, awaiting
/// [`RingDriver::resubmit_errored`] (or failure once the retry budget
/// is spent).
#[derive(Debug, Clone, Copy)]
struct Errored {
    cookie: Cookie,
    status: u16,
    entry: RingEntry,
    attempts: u32,
}

/// Software producer/consumer for one channel's ring pair.
#[derive(Debug)]
pub struct RingDriver {
    channel: usize,
    params: RingParams,
    /// Free-running producer index (slots written + published).
    sq_tail: u64,
    /// Free-running count of slots whose completion was consumed.
    sq_freed: u64,
    /// Free-running CQ consumer index.
    cq_head: u64,
    in_flight: VecDeque<InFlight>,
    next_cookie: Cookie,
    completed: Vec<Cookie>,
    callback_cursor: usize,
    /// Channel-error recovery policy; [`RetryPolicy::none`] fails an
    /// entry on its first error.
    pub retry: RetryPolicy,
    /// Per-cookie CQ status of every retired entry (0 = success).
    statuses: Vec<(Cookie, u16)>,
    /// Errored entries awaiting resubmission or failure.
    errored: VecDeque<Errored>,
    /// Cookies that errored and exhausted the retry budget.
    failed: Vec<Cookie>,
    failed_cursor: usize,
    /// Channel resets issued by [`Self::recover`].
    pub resets_issued: u64,
    /// Entry resubmissions scheduled by the recovery paths.
    pub retries_scheduled: u64,
}

impl RingDriver {
    /// Drive channel `channel`'s rings; `params` must match the
    /// channel's [`crate::dmac::DmacConfig::ring`] geometry.
    pub fn new(channel: usize, params: RingParams) -> Self {
        assert!(params.enabled, "RingDriver needs an enabled ring configuration");
        Self {
            channel,
            params,
            sq_tail: 0,
            sq_freed: 0,
            cq_head: 0,
            in_flight: VecDeque::new(),
            next_cookie: 1,
            completed: Vec::new(),
            callback_cursor: 0,
            retry: RetryPolicy::none(),
            statuses: Vec::new(),
            errored: VecDeque::new(),
            failed: Vec::new(),
            failed_cursor: 0,
            resets_issued: 0,
            retries_scheduled: 0,
        }
    }

    /// Enable bounded resubmit recovery for errored entries.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn channel(&self) -> usize {
        self.channel
    }

    /// Submission slots currently free (producer view).
    pub fn free_slots(&self) -> u64 {
        self.params.sq_entries as u64 - (self.sq_tail - self.sq_freed)
    }

    /// Entries submitted and not yet completed.
    pub fn outstanding(&self) -> usize {
        self.in_flight.len()
    }

    fn slot_addr(&self, index: u64) -> u64 {
        self.params.sq_slot_addr(index)
    }

    fn cq_slot_addr(&self, index: u64) -> u64 {
        self.params.cq_slot_addr(index)
    }

    /// Write `entries` into free submission slots and publish them all
    /// with a single doorbell scheduled at cycle `at` (the caller's
    /// MMIO-cost model decides how far after `sys.now()` that is).
    /// An empty batch still rings the doorbell — a zero-entry doorbell
    /// is a hardware no-op, pinned by the tests below.  A batch that
    /// does not fit the free slots is rejected whole (full-ring
    /// backpressure): nothing is written and no doorbell is rung.
    pub fn submit_batch<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        at: Cycle,
        entries: &[RingEntry],
    ) -> Result<Vec<Cookie>> {
        let needed: u64 = entries.iter().map(RingEntry::slots).sum();
        if needed > self.free_slots() {
            return Err(Error::Driver(format!(
                "submission ring full: batch needs {needed} slots, {} free",
                self.free_slots()
            )));
        }
        for e in entries {
            match *e {
                RingEntry::Memcpy { len, .. } if len == 0 => {
                    return Err(Error::Driver("zero-length ring entry".into()));
                }
                RingEntry::Nd { row_bytes, nd, .. }
                    if row_bytes == 0 || nd.reps.iter().any(|&r| r == 0) =>
                {
                    return Err(Error::Driver("degenerate ND ring entry".into()));
                }
                RingEntry::Nd { .. } if self.params.sq_entries < 2 => {
                    return Err(Error::Driver(
                        "an ND entry needs a ring of at least two slots".into(),
                    ));
                }
                _ => {}
            }
        }
        let mut cookies = Vec::with_capacity(entries.len());
        for e in entries {
            let cookie = self.next_cookie;
            self.next_cookie += 1;
            self.push_entry(sys, *e, cookie, 0);
            cookies.push(cookie);
        }
        sys.schedule_doorbell(at.max(sys.now()), self.channel, self.sq_tail);
        Ok(cookies)
    }

    /// Write one entry into the next free submission slots and track it
    /// in flight (no doorbell — the caller batches that).
    fn push_entry<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        e: RingEntry,
        cookie: Cookie,
        attempts: u32,
    ) {
        let head_slot = (self.sq_tail % self.params.sq_entries as u64) as u32;
        match e {
            RingEntry::Memcpy { dst, src, len } => {
                let d = Descriptor::new(src, dst, len);
                sys.mem.backdoor_write(self.slot_addr(self.sq_tail), &d.to_bytes());
            }
            RingEntry::Nd { dst, src, row_bytes, nd } => {
                debug_assert_eq!(ND_EXT_BYTES, DESC_BYTES);
                let d = Descriptor::new(src, dst, row_bytes).with_nd_levels(nd);
                sys.mem.backdoor_write(self.slot_addr(self.sq_tail), &d.to_bytes());
                sys.mem.backdoor_write(self.slot_addr(self.sq_tail + 1), &nd.to_bytes());
            }
        }
        self.in_flight.push_back(InFlight {
            cookie,
            head_slot,
            slots: e.slots(),
            done: false,
            entry: e,
            attempts,
        });
        self.sq_tail += e.slots();
    }

    /// Consume completion records (phase-bit valid), free the
    /// submission slots they retire, and republish the consumer index
    /// through the CQ doorbell at cycle `at`.  Returns the cookies
    /// retired by this poll, in CQ order — including errored entries,
    /// whose nonzero CQ status is surfaced through
    /// [`status_of`](Self::status_of) / [`take_failed`](Self::take_failed)
    /// rather than completing them.
    pub fn poll_completions<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        at: Cycle,
    ) -> Vec<Cookie> {
        let mut newly = Vec::new();
        loop {
            let rec =
                CqRecord::from_bytes(sys.mem.backdoor_read(self.cq_slot_addr(self.cq_head), 8));
            if rec.phase != CqRecord::phase_of(self.cq_head, self.params.cq_entries) {
                break;
            }
            let entry = self
                .in_flight
                .iter_mut()
                .find(|f| !f.done && f.head_slot == rec.sq_slot)
                .expect("completion record for an unknown submission slot");
            entry.done = true;
            newly.push(entry.cookie);
            self.statuses.push((entry.cookie, rec.status));
            if rec.status == 0 {
                self.completed.push(entry.cookie);
            } else {
                self.errored.push_back(Errored {
                    cookie: entry.cookie,
                    status: rec.status,
                    entry: entry.entry,
                    attempts: entry.attempts,
                });
            }
            self.cq_head += 1;
        }
        // Slots free strictly in ring order: release the contiguous
        // completed prefix (a later entry completing first keeps its
        // slots allocated until everything before it retires).
        while self.in_flight.front().is_some_and(|f| f.done) {
            let f = self.in_flight.pop_front().unwrap();
            self.sq_freed += f.slots;
        }
        if !newly.is_empty() {
            sys.schedule_cq_doorbell(at.max(sys.now()), self.channel, self.cq_head);
        }
        newly
    }

    /// Resubmit every errored entry whose retry budget allows it (same
    /// cookie, fresh submission slots, one doorbell); entries beyond
    /// the budget fail.  Returns the resubmitted cookies.
    pub fn resubmit_errored<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        at: Cycle,
    ) -> Vec<Cookie> {
        let mut resubmitted = Vec::new();
        let mut max_attempts = 0;
        while let Some(e) = self.errored.pop_front() {
            if self.retry.allows(e.attempts) && e.entry.slots() <= self.free_slots() {
                max_attempts = max_attempts.max(e.attempts);
                self.retries_scheduled += 1;
                self.push_entry(sys, e.entry, e.cookie, e.attempts + 1);
                resubmitted.push(e.cookie);
            } else {
                self.failed.push(e.cookie);
            }
        }
        if !resubmitted.is_empty() {
            let delay = 1 + self.retry.backoff(max_attempts);
            sys.schedule_doorbell(at.max(sys.now()) + delay, self.channel, self.sq_tail);
        }
        resubmitted
    }

    /// Recover a *halted* channel (sticky error CSR): reset it, zero
    /// the CQ memory (the hardware ring state restarts at index 0, so
    /// stale records would alias the fresh phase parity), rebuild the
    /// software ring view, and resubmit everything that was in flight.
    /// Counts one attempt against each resubmitted entry; entries
    /// beyond the retry budget fail.  Returns the resubmitted cookies.
    pub fn recover<C: Controller>(&mut self, sys: &mut System<C>, at: Cycle) -> Vec<Cookie> {
        let t = at.max(sys.now());
        sys.schedule_reset(t, self.channel);
        self.resets_issued += 1;
        for i in 0..self.params.cq_entries as u64 {
            sys.mem.backdoor_write(self.cq_slot_addr(i), &[0u8; CQ_RECORD_BYTES as usize]);
        }
        self.sq_tail = 0;
        self.sq_freed = 0;
        self.cq_head = 0;
        let pending: Vec<InFlight> = std::mem::take(&mut self.in_flight).into();
        let mut resubmitted = Vec::new();
        let mut max_attempts = 0;
        for f in pending {
            if f.done {
                // Already retired (out of order, behind an undone
                // head): its status is recorded; nothing to resubmit.
                continue;
            }
            if self.retry.allows(f.attempts) {
                max_attempts = max_attempts.max(f.attempts);
                self.retries_scheduled += 1;
                self.push_entry(sys, f.entry, f.cookie, f.attempts + 1);
                resubmitted.push(f.cookie);
            } else {
                self.statuses.push((f.cookie, crate::axi::ERR_TIMEOUT));
                self.failed.push(f.cookie);
            }
        }
        if !resubmitted.is_empty() {
            let delay = 1 + self.retry.backoff(max_attempts);
            sys.schedule_doorbell(t + delay, self.channel, self.sq_tail);
        }
        resubmitted
    }

    /// [`poll_completions`](Self::poll_completions) with the CQ
    /// doorbell scheduled immediately (the common polling-loop call).
    pub fn poll_now<C: Controller>(&mut self, sys: &mut System<C>) -> Vec<Cookie> {
        let now = sys.now();
        self.poll_completions(sys, now)
    }

    /// [`submit_batch`](Self::submit_batch) with the doorbell
    /// scheduled immediately.
    pub fn submit_now<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        entries: &[RingEntry],
    ) -> Result<Vec<Cookie>> {
        let now = sys.now();
        self.submit_batch(sys, now, entries)
    }

    /// `dma_async_is_tx_complete` equivalent.
    pub fn is_complete(&self, cookie: Cookie) -> bool {
        self.completed.contains(&cookie)
    }

    /// Latest CQ status of `cookie`: `None` until a record retires it,
    /// `Some(0)` on success, `Some(code)` on error (a resubmitted
    /// entry's later success appends a newer status).
    pub fn status_of(&self, cookie: Cookie) -> Option<u16> {
        self.statuses.iter().rev().find(|&&(c, _)| c == cookie).map(|&(_, s)| s)
    }

    /// The entry errored and exhausted its retry budget.
    pub fn is_failed(&self, cookie: Cookie) -> bool {
        self.failed.contains(&cookie)
    }

    /// Completion callbacks fired since the last call.
    pub fn take_completed(&mut self) -> Vec<Cookie> {
        let new = self.completed[self.callback_cursor..].to_vec();
        self.callback_cursor = self.completed.len();
        new
    }

    /// Failure callbacks fired since the last call.
    pub fn take_failed(&mut self) -> Vec<Cookie> {
        let new = self.failed[self.failed_cursor..].to_vec();
        self.failed_cursor = self.failed.len();
        new
    }

    fn set_next_cookie(&mut self, cookie: Cookie) {
        self.next_cookie = cookie;
    }

    fn next_cookie(&self) -> Cookie {
        self.next_cookie
    }
}

/// Per-client virtual channel of the multi-tenant ring driver.
#[derive(Debug, Clone)]
struct RingVchan {
    pinned: Option<usize>,
    cookies: Vec<Cookie>,
}

/// Many client submission queues multiplexed onto per-channel hardware
/// rings — the ring-mode analogue of [`super::MultiTenantDriver`].
#[derive(Debug)]
pub struct MultiRingDriver {
    rings: Vec<RingDriver>,
    vchans: Vec<RingVchan>,
    /// Globally monotone cookie counter shared by every ring.
    next_cookie: Cookie,
}

impl MultiRingDriver {
    /// One [`RingDriver`] per channel configuration; every entry must
    /// have rings enabled ([`crate::dmac::DmacConfig::ring`]).
    pub fn new(ring_params: &[RingParams]) -> Self {
        assert!(!ring_params.is_empty(), "at least one channel");
        Self {
            rings: ring_params
                .iter()
                .enumerate()
                .map(|(ch, &p)| RingDriver::new(ch, p))
                .collect(),
            vchans: Vec::new(),
            next_cookie: 1,
        }
    }

    pub fn num_channels(&self) -> usize {
        self.rings.len()
    }

    pub fn ring(&self, ch: usize) -> &RingDriver {
        &self.rings[ch]
    }

    /// Open a client submission queue with least-loaded placement.
    pub fn open(&mut self) -> VchanId {
        self.vchans.push(RingVchan { pinned: None, cookies: Vec::new() });
        self.vchans.len() - 1
    }

    /// Open a client submission queue pinned to channel `ch`.
    pub fn open_pinned(&mut self, ch: usize) -> Result<VchanId> {
        if ch >= self.rings.len() {
            return Err(Error::Driver(format!(
                "cannot pin to channel {ch}: only {} channels",
                self.rings.len()
            )));
        }
        self.vchans.push(RingVchan { pinned: Some(ch), cookies: Vec::new() });
        Ok(self.vchans.len() - 1)
    }

    /// Candidate channels in placement order: the pin, or every
    /// channel sorted by outstanding entries (ties to the lowest id —
    /// deterministic), falling back across full rings.
    fn placement_order(&self, vchan: VchanId) -> Vec<usize> {
        match self.vchans[vchan].pinned {
            Some(ch) => vec![ch],
            None => {
                let mut order: Vec<usize> = (0..self.rings.len()).collect();
                order.sort_by_key(|&i| (self.rings[i].outstanding(), i));
                order
            }
        }
    }

    /// Submit one batch from `vchan`: placed on one channel's ring
    /// (batches are never split across rings — one doorbell each) with
    /// globally monotone client-visible cookies.
    pub fn submit_batch<C: Controller>(
        &mut self,
        vchan: VchanId,
        sys: &mut System<C>,
        at: Cycle,
        entries: &[RingEntry],
    ) -> Result<Vec<Cookie>> {
        let mut last_err = None;
        for ch in self.placement_order(vchan) {
            self.rings[ch].set_next_cookie(self.next_cookie);
            match self.rings[ch].submit_batch(sys, at, entries) {
                Ok(cookies) => {
                    self.next_cookie = self.rings[ch].next_cookie();
                    self.vchans[vchan].cookies.extend(cookies.iter().copied());
                    return Ok(cookies);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one candidate channel"))
    }

    /// Poll every channel's completion ring (deterministic channel
    /// order), returning the cookies completed by this sweep.
    pub fn poll_completions<C: Controller>(
        &mut self,
        sys: &mut System<C>,
        at: Cycle,
    ) -> Vec<Cookie> {
        let mut newly = Vec::new();
        for r in &mut self.rings {
            newly.extend(r.poll_completions(sys, at));
        }
        newly
    }

    /// [`poll_completions`](Self::poll_completions) with the CQ
    /// doorbells scheduled immediately.
    pub fn poll_now<C: Controller>(&mut self, sys: &mut System<C>) -> Vec<Cookie> {
        let now = sys.now();
        self.poll_completions(sys, now)
    }

    pub fn is_complete(&self, cookie: Cookie) -> bool {
        self.rings.iter().any(|r| r.is_complete(cookie))
    }

    /// Cookies issued to `vchan`, in submission order.
    pub fn cookies_of(&self, vchan: VchanId) -> &[Cookie] {
        &self.vchans[vchan].cookies
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmac::{Dmac, DmacConfig, MultiChannel};
    use crate::mem::backdoor::fill_pattern;
    use crate::mem::LatencyProfile;
    use crate::workload::map;

    const SQ: u64 = map::DESC_BASE;
    const CQ: u64 = map::DESC_BASE + 0x10_0000;

    fn ring_params(sq_entries: u32, cq_entries: u32) -> RingParams {
        RingParams::enabled(SQ, sq_entries, CQ, cq_entries)
    }

    fn ring_system(params: RingParams) -> System<Dmac> {
        System::new(
            LatencyProfile::Ddr3,
            Dmac::new(DmacConfig::speculation().with_ring(params)),
        )
    }

    #[test]
    fn batch_round_trip_moves_bytes_with_one_doorbell_and_one_irq() {
        let params = ring_params(64, 64).with_coalescing(8, 10_000);
        let mut sys = ring_system(params);
        let mut drv = RingDriver::new(0, params);
        fill_pattern(&mut sys.mem, map::SRC_BASE, 8 * 4096, 7);
        let entries: Vec<RingEntry> = (0..8u64)
            .map(|i| RingEntry::Memcpy {
                dst: map::DST_BASE + i * 4096,
                src: map::SRC_BASE + i * 4096,
                len: 512,
            })
            .collect();
        let cookies = drv.submit_batch(&mut sys, 0, &entries).unwrap();
        assert_eq!(cookies.len(), 8);
        assert_eq!(drv.free_slots(), 64 - 8);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 8);
        assert_eq!(stats.ring_doorbells, 1, "one doorbell published the whole batch");
        assert_eq!(stats.ring_entries, 8);
        assert_eq!(stats.cq_records, 8);
        assert_eq!(stats.irqs, 1, "8 completions coalesce into one IRQ");
        assert_eq!(sys.ring_irq_edges, vec![1]);
        for i in 0..8u64 {
            assert_eq!(
                sys.mem.backdoor_read(map::SRC_BASE + i * 4096, 512).to_vec(),
                sys.mem.backdoor_read(map::DST_BASE + i * 4096, 512).to_vec(),
                "transfer {i}"
            );
        }
        let done = drv.poll_now(&mut sys);
        assert_eq!(done, cookies, "records consumed in ring order");
        assert_eq!(drv.free_slots(), 64, "slots freed after consumption");
        assert!(cookies.iter().all(|&c| drv.is_complete(c)));
    }

    #[test]
    fn full_ring_backpressure_rejects_the_whole_batch() {
        // Satellite pin: the producer catching the consumer is
        // backpressure at the driver, not silent overwrite.
        let params = ring_params(4, 8);
        let mut sys = ring_system(params);
        let mut drv = RingDriver::new(0, params);
        fill_pattern(&mut sys.mem, map::SRC_BASE, 4096, 3);
        let e = |i: u64| RingEntry::Memcpy {
            dst: map::DST_BASE + i * 4096,
            src: map::SRC_BASE,
            len: 64,
        };
        drv.submit_batch(&mut sys, 0, &[e(0), e(1), e(2), e(3)]).unwrap();
        assert_eq!(drv.free_slots(), 0);
        let err = drv.submit_batch(&mut sys, 0, &[e(4)]);
        assert!(matches!(err, Err(Error::Driver(_))), "full ring must backpressure");
        sys.run_until_idle().unwrap();
        assert_eq!(drv.poll_now(&mut sys).len(), 4);
        assert_eq!(drv.free_slots(), 4);
        // Freed slots accept the deferred entry (second lap).
        drv.submit_now(&mut sys, &[e(4)]).unwrap();
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(drv.poll_now(&mut sys).len(), 1);
    }

    #[test]
    fn zero_entry_doorbell_is_a_hardware_noop() {
        // Satellite pin: a doorbell publishing nothing fetches nothing.
        let params = ring_params(8, 8);
        let mut sys = ring_system(params);
        let mut drv = RingDriver::new(0, params);
        let cookies = drv.submit_batch(&mut sys, 0, &[]).unwrap();
        assert!(cookies.is_empty());
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.ring_doorbells, 1, "the doorbell write itself still lands");
        assert_eq!(stats.ring_entries, 0);
        assert_eq!(stats.desc_beats, 0, "no descriptor fetch was issued");
        assert_eq!(stats.irqs, 0);
        assert!(drv.poll_now(&mut sys).is_empty());
    }

    #[test]
    fn nd_entries_wrap_the_extension_word_to_slot_zero() {
        // Satellite pin (wrap-around at the top index): an ND head in
        // the last slot continues its extension word at slot 0 on the
        // next lap, and the rows still land byte-exact.
        let params = ring_params(4, 8);
        let mut sys = ring_system(params);
        let mut drv = RingDriver::new(0, params);
        fill_pattern(&mut sys.mem, map::SRC_BASE, 16 << 10, 9);
        // Lap 0: three linear entries (slots 0-2).
        let lin: Vec<RingEntry> = (0..3u64)
            .map(|i| RingEntry::Memcpy {
                dst: map::DST_BASE + i * 4096,
                src: map::SRC_BASE + i * 4096,
                len: 128,
            })
            .collect();
        drv.submit_batch(&mut sys, 0, &lin).unwrap();
        sys.run_until_idle().unwrap();
        assert_eq!(drv.poll_now(&mut sys).len(), 3);
        // Lap boundary: the ND head lands in slot 3 (top index), its
        // extension wraps to slot 0.
        let nd = NdExt { reps: [4, 1], src_stride: [1024, 0], dst_stride: [256, 0] };
        let cookies = drv
            .submit_batch(
                &mut sys,
                sys.now(),
                &[RingEntry::Nd {
                    dst: map::DST_BASE + 0x40000,
                    src: map::SRC_BASE,
                    row_bytes: 256,
                    nd,
                }],
            )
            .unwrap();
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.nd_descriptors, 1);
        assert_eq!(stats.nd_rows, 4);
        assert_eq!(drv.poll_now(&mut sys), cookies);
        for r in 0..4u64 {
            assert_eq!(
                sys.mem.backdoor_read(map::SRC_BASE + r * 1024, 256).to_vec(),
                sys.mem.backdoor_read(map::DST_BASE + 0x40000 + r * 256, 256).to_vec(),
                "row {r}"
            );
        }
    }

    #[test]
    fn nd_entry_rejected_on_a_one_slot_ring() {
        let params = ring_params(1, 4);
        let mut sys = ring_system(params);
        let mut drv = RingDriver::new(0, params);
        let nd = NdExt::linear();
        let err = drv.submit_batch(
            &mut sys,
            0,
            &[RingEntry::Nd { dst: map::DST_BASE, src: map::SRC_BASE, row_bytes: 64, nd }],
        );
        assert!(matches!(err, Err(Error::Driver(_))));
    }

    #[test]
    fn errored_entry_surfaces_its_cq_status_and_fails_without_retry() {
        use crate::axi::ERR_DECERR;
        use crate::mem::FaultConfig;
        // One entry reads from a DECERR hole, one from healthy memory:
        // both retire through the CQ, only the healthy one completes.
        let params = ring_params(16, 16);
        let cfg = DmacConfig::speculation().with_ring(params).with_faults(
            FaultConfig::seeded(21).with_decerr_window(map::SRC_BASE, map::SRC_BASE + 0x100),
        );
        let mut sys = System::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        let mut drv = RingDriver::new(0, params);
        fill_pattern(&mut sys.mem, map::SRC_BASE + 0x1000, 256, 4);
        let bad = RingEntry::Memcpy { dst: map::DST_BASE, src: map::SRC_BASE, len: 64 };
        let good =
            RingEntry::Memcpy { dst: map::DST_BASE + 4096, src: map::SRC_BASE + 0x1000, len: 256 };
        let cookies = drv.submit_batch(&mut sys, 0, &[bad, good]).unwrap();
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.cq_records, 2, "errored entries still retire through the CQ");
        assert_eq!(stats.cq_error_records, 1);
        assert_eq!(stats.aborted_transfers, 1);
        assert!(sys.ctrl.error_csr(0).is_none(), "ring data errors never halt the channel");
        let retired = drv.poll_now(&mut sys);
        assert_eq!(retired.len(), 2);
        assert_eq!(drv.status_of(cookies[0]), Some(ERR_DECERR));
        assert_eq!(drv.status_of(cookies[1]), Some(0));
        assert!(!drv.is_complete(cookies[0]));
        assert!(drv.is_complete(cookies[1]));
        // Default policy: no retries — the errored cookie fails.
        assert!(drv.resubmit_errored(&mut sys, sys.now()).is_empty());
        assert_eq!(drv.take_failed(), vec![cookies[0]]);
        assert!(drv.is_failed(cookies[0]));
    }

    #[test]
    fn halted_ring_channel_recovers_and_the_entry_completes() {
        use crate::mem::FaultConfig;
        // Exactly one SLVERR, landing on the first read beat — the SQ
        // descriptor fetch — so the channel halts with a sticky error
        // CSR and the published entry freezes.
        let params = ring_params(16, 16);
        let cfg = DmacConfig::speculation()
            .with_ring(params)
            .with_faults(FaultConfig::seeded(22).with_read_slverr(1_000_000).with_max_faults(1));
        let mut sys = System::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        let mut drv =
            RingDriver::new(0, params).with_retry(crate::driver::RetryPolicy::bounded(2, 16));
        fill_pattern(&mut sys.mem, map::SRC_BASE, 512, 6);
        let cookies = drv
            .submit_now(&mut sys, &[RingEntry::Memcpy {
                dst: map::DST_BASE,
                src: map::SRC_BASE,
                len: 512,
            }])
            .unwrap();
        sys.run_until_idle().unwrap();
        assert!(sys.ctrl.error_csr(0).is_some(), "SQ fetch fault halts the channel");
        assert!(drv.poll_now(&mut sys).is_empty(), "nothing retired before recovery");
        // Reset, rewrite, resubmit: the fault budget is spent, so the
        // retry runs on a clean bus.
        let now = sys.now();
        let resubmitted = drv.recover(&mut sys, now);
        assert_eq!(resubmitted, cookies);
        assert_eq!(drv.resets_issued, 1);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.channel_resets, 1);
        assert!(sys.ctrl.error_csr(0).is_none());
        assert_eq!(drv.poll_now(&mut sys), cookies);
        assert_eq!(drv.status_of(cookies[0]), Some(0));
        assert!(drv.is_complete(cookies[0]));
        assert_eq!(
            sys.mem.backdoor_read(map::SRC_BASE, 512).to_vec(),
            sys.mem.backdoor_read(map::DST_BASE, 512).to_vec()
        );
    }

    #[test]
    fn multi_ring_driver_multiplexes_vchans_with_monotone_cookies() {
        let p0 = ring_params(32, 32);
        let p1 = RingParams::enabled(SQ + 0x8000, 32, CQ + 0x8000, 32);
        let mut sys = System::new(
            LatencyProfile::Ddr3,
            MultiChannel::new(&[
                DmacConfig::speculation().with_ring(p0),
                DmacConfig::speculation().with_ring(p1),
            ]),
        );
        let mut drv = MultiRingDriver::new(&[p0, p1]);
        fill_pattern(&mut sys.mem, map::SRC_BASE, 8 * 4096, 5);
        let a = drv.open();
        let b = drv.open_pinned(1).unwrap();
        assert!(drv.open_pinned(7).is_err());
        let e = |i: u64| RingEntry::Memcpy {
            dst: map::DST_BASE + i * 4096,
            src: map::SRC_BASE + (i % 8) * 4096,
            len: 256,
        };
        // a's first batch lands on the least-loaded channel 0; b is
        // pinned to channel 1; a's second batch balances onto... the
        // channel with fewer outstanding entries (deterministic).
        let ca0 = drv.submit_batch(a, &mut sys, 0, &[e(0), e(1)]).unwrap();
        let cb = drv.submit_batch(b, &mut sys, 0, &[e(2)]).unwrap();
        let ca1 = drv.submit_batch(a, &mut sys, 0, &[e(3)]).unwrap();
        assert_eq!(drv.ring(0).outstanding(), 2);
        assert_eq!(drv.ring(1).outstanding(), 2, "second a-batch balanced to channel 1");
        // Globally monotone, unique cookies across vchans and rings.
        let mut all: Vec<Cookie> = ca0.iter().chain(&cb).chain(&ca1).copied().collect();
        assert!(all.windows(2).all(|w| w[1] > w[0]));
        all.dedup();
        assert_eq!(all.len(), 4);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 4);
        let done = drv.poll_now(&mut sys);
        assert_eq!(done.len(), 4);
        for &c in &all {
            assert!(drv.is_complete(c), "cookie {c}");
        }
        assert_eq!(drv.cookies_of(a).len(), 3);
        assert_eq!(drv.cookies_of(b), &cb[..]);
        sys.run_until_idle().unwrap();
    }
}
