//! Linux-style `dma_map` / `dma_unmap` layer over the IOMMU.
//!
//! [`DmaMapper`] owns a slice of physical memory for SV39 page-table
//! pages (allocated via the [`crate::mem`] backdoor, exactly like the
//! testbench loads descriptors) and a bump allocator over a guest-
//! virtual IOVA window.  `dma_map` wires scattered physical pages into
//! IOVA-contiguous ranges; the DMAC then streams a *linear* descriptor
//! chain through paged, non-contiguous memory — the canonical irregular
//! transfer the paper motivates.
//!
//! Fault recovery (`handle_fault`): map the missing page at the faulted
//! IOVA, then [`crate::iommu::IommuDmac::resume`] relaunches the
//! stalled translation from the page-table root.

use crate::iommu::pagetable::{
    pte_is_leaf, pte_leaf, pte_table, pte_target, pte_valid, vpn_index, vpn_of, PAGE_SIZE,
    PTE_BYTES, PT_LEVELS,
};
use crate::mem::Memory;
use crate::{Error, Result};

/// One mapped IOVA range returned by [`DmaMapper::dma_map`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaMapping {
    /// First mapped IOVA byte (carries the physical page offset).
    pub iova: u64,
    /// Length in bytes, as requested.
    pub len: u64,
}

#[derive(Debug, Clone)]
pub struct DmaMapper {
    pt_base: u64,
    pt_size: u64,
    pt_cursor: u64,
    root: u64,
    iova_cursor: u64,
}

impl DmaMapper {
    /// Carve page-table pages out of `[pt_base, pt_base + pt_size)` and
    /// hand out IOVAs from `iova_base` up.  Allocates and zeroes the
    /// root table immediately.
    pub fn new(mem: &mut Memory, pt_base: u64, pt_size: u64, iova_base: u64) -> Result<Self> {
        if pt_base % PAGE_SIZE != 0 || pt_size % PAGE_SIZE != 0 {
            return Err(Error::Driver("page-table region must be page-aligned".into()));
        }
        let mut m = Self { pt_base, pt_size, pt_cursor: 0, root: 0, iova_cursor: iova_base };
        m.root = m.alloc_table_page(mem)?;
        Ok(m)
    }

    /// Physical address of the root table (written into the IOMMU's
    /// root CSR via [`crate::iommu::IommuDmac::set_root`]).
    pub fn root(&self) -> u64 {
        self.root
    }

    /// Page-table pages allocated so far (root included).
    pub fn table_pages(&self) -> u64 {
        self.pt_cursor / PAGE_SIZE
    }

    fn alloc_table_page(&mut self, mem: &mut Memory) -> Result<u64> {
        if self.pt_cursor + PAGE_SIZE > self.pt_size {
            return Err(Error::Driver("page-table pool exhausted".into()));
        }
        let page = self.pt_base + self.pt_cursor;
        self.pt_cursor += PAGE_SIZE;
        mem.backdoor_write(page, &[0u8; PAGE_SIZE as usize]);
        Ok(page)
    }

    /// Walk (and grow) the tables down to the leaf level for `iova`,
    /// returning the physical address of its leaf PTE slot.
    fn leaf_slot(&mut self, mem: &mut Memory, iova: u64, grow: bool) -> Result<u64> {
        let vpn = vpn_of(iova);
        let mut table = self.root;
        for level in (1..PT_LEVELS).rev() {
            let slot = table + vpn_index(vpn, level) * PTE_BYTES;
            let pte = mem.backdoor_read_u64(slot);
            table = if pte_valid(pte) {
                if pte_is_leaf(pte) {
                    return Err(Error::Driver(format!(
                        "superpage PTE at level {level} for iova {iova:#x}"
                    )));
                }
                pte_target(pte)
            } else {
                if !grow {
                    return Err(Error::Driver(format!("iova {iova:#x} not mapped")));
                }
                let page = self.alloc_table_page(mem)?;
                mem.backdoor_write_u64(slot, pte_table(page));
                page
            };
        }
        Ok(table + vpn_index(vpn, 0) * PTE_BYTES)
    }

    /// Map the 4 KiB page containing `iova` onto the physical page at
    /// `pa` (both page-aligned).  Remapping an existing entry is
    /// allowed — that is exactly what fault recovery does.
    pub fn map_page(&mut self, mem: &mut Memory, iova: u64, pa: u64) -> Result<()> {
        if iova % PAGE_SIZE != 0 || pa % PAGE_SIZE != 0 {
            return Err(Error::Driver("map_page needs page-aligned iova and pa".into()));
        }
        let slot = self.leaf_slot(mem, iova, true)?;
        mem.backdoor_write_u64(slot, pte_leaf(pa));
        Ok(())
    }

    /// Invalidate the leaf PTE for `iova`.  The caller must also shoot
    /// down the IOTLB ([`crate::iommu::Mmu::flush_iova`]).
    pub fn unmap_page(&mut self, mem: &mut Memory, iova: u64) -> Result<()> {
        let slot = self.leaf_slot(mem, iova, false)?;
        if !pte_valid(mem.backdoor_read_u64(slot)) {
            return Err(Error::Driver(format!("iova {iova:#x} not mapped")));
        }
        mem.backdoor_write_u64(slot, 0);
        Ok(())
    }

    /// Identity-map `[base, base + len)` (page-rounded): used for the
    /// descriptor pool, so CSR launch addresses and completion stamps
    /// keep their physical values while still exercising translation.
    pub fn map_identity(&mut self, mem: &mut Memory, base: u64, len: u64) -> Result<()> {
        let first = base & !(PAGE_SIZE - 1);
        let last = (base + len + PAGE_SIZE - 1) & !(PAGE_SIZE - 1);
        let mut page = first;
        while page < last {
            self.map_page(mem, page, page)?;
            page += PAGE_SIZE;
        }
        Ok(())
    }

    /// Map `[pa, pa + len)` at a fresh IOVA range (page offset
    /// preserved).  This is `dma_map_single`: one physically contiguous
    /// buffer, one IOVA range.
    pub fn dma_map(&mut self, mem: &mut Memory, pa: u64, len: u64) -> Result<DmaMapping> {
        if len == 0 {
            return Err(Error::Driver("zero-length dma_map".into()));
        }
        let off = pa % PAGE_SIZE;
        let first = pa - off;
        let pages = (off + len).div_ceil(PAGE_SIZE);
        let iova0 = self.iova_cursor;
        self.iova_cursor += pages * PAGE_SIZE;
        for i in 0..pages {
            self.map_page(mem, iova0 + i * PAGE_SIZE, first + i * PAGE_SIZE)?;
        }
        Ok(DmaMapping { iova: iova0 + off, len })
    }

    /// `dma_map_sg`: one IOVA range per scatter-gather element.  The
    /// returned list pairs with the element order, ready to hand to
    /// [`super::DmaDriver::prep_sg`] /
    /// [`super::MultiTenantDriver::submit_sg`].
    pub fn dma_map_sg(&mut self, mem: &mut Memory, sg: &[(u64, u64)]) -> Result<Vec<DmaMapping>> {
        sg.iter().map(|&(pa, len)| self.dma_map(mem, pa, len)).collect()
    }

    /// Tear down a mapping's leaf PTEs (table pages are not recycled,
    /// like a bump-allocated kernel pool between `dma_free` batches).
    pub fn dma_unmap(&mut self, mem: &mut Memory, mapping: DmaMapping) -> Result<()> {
        let first = mapping.iova & !(PAGE_SIZE - 1);
        let pages = (mapping.iova % PAGE_SIZE + mapping.len).div_ceil(PAGE_SIZE);
        for i in 0..pages {
            self.unmap_page(mem, first + i * PAGE_SIZE)?;
        }
        Ok(())
    }

    /// Software walk of the tables this mapper built — the test oracle
    /// for what the hardware walker should resolve.
    pub fn translate(&self, mem: &Memory, iova: u64) -> Option<u64> {
        let vpn = vpn_of(iova);
        let mut table = self.root;
        for level in (0..PT_LEVELS).rev() {
            let pte = mem.backdoor_read_u64(table + vpn_index(vpn, level) * PTE_BYTES);
            if !pte_valid(pte) {
                return None;
            }
            if pte_is_leaf(pte) {
                return (level == 0).then(|| pte_target(pte) + iova % PAGE_SIZE);
            }
            table = pte_target(pte);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::LatencyProfile;
    use crate::workload::map;

    fn setup() -> (Memory, DmaMapper) {
        let mut mem = Memory::new(crate::tb::DEFAULT_MEM_BYTES, LatencyProfile::Ideal);
        let mapper = DmaMapper::new(&mut mem, map::PT_BASE, map::PT_SIZE, map::IOVA_BASE).unwrap();
        (mem, mapper)
    }

    #[test]
    fn map_and_translate_round_trip() {
        let (mut mem, mut m) = setup();
        m.map_page(&mut mem, map::IOVA_BASE, map::SRC_BASE).unwrap();
        assert_eq!(m.translate(&mem, map::IOVA_BASE + 0x123), Some(map::SRC_BASE + 0x123));
        assert_eq!(m.translate(&mem, map::IOVA_BASE + PAGE_SIZE), None);
        // Three table pages: root + one L1 + one L0.
        assert_eq!(m.table_pages(), 3);
    }

    #[test]
    fn dma_map_preserves_page_offset_and_is_contiguous() {
        let (mut mem, mut m) = setup();
        let mapping = m.dma_map(&mut mem, map::SRC_BASE + 0x40, 2 * PAGE_SIZE).unwrap();
        assert_eq!(mapping.iova % PAGE_SIZE, 0x40);
        assert_eq!(mapping.len, 2 * PAGE_SIZE);
        for off in [0u64, 0x1000, 0x1FBF] {
            assert_eq!(
                m.translate(&mem, mapping.iova + off),
                Some(map::SRC_BASE + 0x40 + off),
                "offset {off:#x}"
            );
        }
    }

    #[test]
    fn dma_map_sg_gives_each_element_its_own_range() {
        let (mut mem, mut m) = setup();
        let sg =
            [(map::SRC_BASE, 64u64), (map::SRC_BASE + 8 * PAGE_SIZE, 64), (map::DST_BASE, 4096)];
        let maps = m.dma_map_sg(&mut mem, &sg).unwrap();
        assert_eq!(maps.len(), 3);
        for (mapping, &(pa, len)) in maps.iter().zip(&sg) {
            assert_eq!(mapping.len, len);
            assert_eq!(m.translate(&mem, mapping.iova), Some(pa));
        }
        // Ranges never overlap.
        assert!(maps[0].iova + PAGE_SIZE <= maps[1].iova);
        assert!(maps[1].iova + PAGE_SIZE <= maps[2].iova);
    }

    #[test]
    fn unmap_invalidates_and_double_unmap_errors() {
        let (mut mem, mut m) = setup();
        let mapping = m.dma_map(&mut mem, map::SRC_BASE, 100).unwrap();
        m.dma_unmap(&mut mem, mapping).unwrap();
        assert_eq!(m.translate(&mem, mapping.iova), None);
        assert!(m.dma_unmap(&mut mem, mapping).is_err());
    }

    #[test]
    fn identity_map_covers_partial_pages() {
        let (mut mem, mut m) = setup();
        m.map_identity(&mut mem, map::DESC_BASE + 8, 0x1800).unwrap();
        assert_eq!(m.translate(&mem, map::DESC_BASE), Some(map::DESC_BASE));
        assert_eq!(
            m.translate(&mem, map::DESC_BASE + 0x1FFF),
            Some(map::DESC_BASE + 0x1FFF),
            "rounded up to the covering page"
        );
    }

    #[test]
    fn pool_exhaustion_is_a_driver_error() {
        let mut mem = Memory::new(crate::tb::DEFAULT_MEM_BYTES, LatencyProfile::Ideal);
        // Room for root + L1 + one L0 table only.
        let mut m = DmaMapper::new(&mut mem, map::PT_BASE, 3 * PAGE_SIZE, map::IOVA_BASE).unwrap();
        m.map_page(&mut mem, map::IOVA_BASE, map::SRC_BASE).unwrap();
        // A far-away iova needs fresh L1+L0 tables: exhausted.
        let far = map::IOVA_BASE + (1 << 30);
        assert!(matches!(m.map_page(&mut mem, far, map::SRC_BASE), Err(Error::Driver(_))));
    }

    #[test]
    fn remap_overwrites_in_place() {
        let (mut mem, mut m) = setup();
        m.map_page(&mut mem, map::IOVA_BASE, map::SRC_BASE).unwrap();
        m.map_page(&mut mem, map::IOVA_BASE, map::DST_BASE).unwrap();
        assert_eq!(m.translate(&mem, map::IOVA_BASE), Some(map::DST_BASE));
    }
}
