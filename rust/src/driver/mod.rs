//! Linux driver model (paper §II-E).
//!
//! The paper ships a Linux `dmaengine` driver implementing the *memcpy*
//! API.  This module reproduces the driver's protocol against the
//! simulated SoC:
//!
//! 1. **prepare** (`device_prep_dma_memcpy`): allocate one or more
//!    chained descriptors and populate `source`, `destination`,
//!    `length`, `config`; if a transfer needs several descriptors,
//!    only the last has IRQ signalling enabled.
//! 2. **commit** (`tx_submit`): chain committed transfers FIFO into a
//!    new chain.
//! 3. **submit** (`issue_pending`): if fewer than the maximum number
//!    of allowed chains are running, schedule the chain with a CSR
//!    write; otherwise store it for later.
//! 4. **interrupt handler**: on IRQ, detect completed chains through
//!    the in-memory completion stamps, schedule completion callbacks,
//!    decrement the active count, and launch stored chains.

pub mod dmaengine;
pub mod mapper;
pub mod multitenant;
pub mod retry;
pub mod rings;

pub use dmaengine::{Cookie, DmaDriver, Tx};
pub use mapper::{DmaMapper, DmaMapping};
pub use multitenant::{MultiTenantDriver, VchanId};
pub use retry::RetryPolicy;
pub use rings::{MultiRingDriver, RingDriver, RingEntry};
