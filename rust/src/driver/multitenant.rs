//! Multi-tenant allocator: many client submission queues (*virtual
//! channels*) multiplexed onto the physical DMAC channels.
//!
//! The Linux dmaengine framework hands every client a `dma_chan`; on
//! hardware with fewer physical channels than clients, the driver
//! multiplexes.  This model reproduces that layer on top of
//! [`DmaDriver`] (one instance per physical channel, each owning a
//! slice of the descriptor pool and launching on its banked CSR):
//!
//! * **virtual channels** are opened per client, either *pinned* to a
//!   physical channel or placed *least-loaded* (fewest outstanding
//!   payload bytes, ties to the lowest channel id — deterministic);
//! * **cookies** are drawn from one global monotone counter, so each
//!   client observes a strictly increasing cookie sequence no matter
//!   how its transfers were placed;
//! * the **interrupt handler** is shared: every physical channel's
//!   chains are scanned for completion stamps, stored chains are
//!   promoted per channel, and completion callbacks fire in channel
//!   order (deterministic);
//! * a vchan whose requests keep failing after the per-channel
//!   [`RetryPolicy`] is exhausted gets **quarantined**: further
//!   submissions are rejected so one misbehaving client (a bad IOVA
//!   range, an unbacked window) cannot monopolise the retry machinery
//!   while healthy clients starve.

use super::dmaengine::{Cookie, DmaDriver};
use super::retry::RetryPolicy;
use crate::dmac::descriptor::NdExt;
use crate::dmac::{Controller, DESC_BYTES};
use crate::sim::Cycle;
use crate::tb::System;
use crate::{Error, Result};

/// Handle of a client submission queue.
pub type VchanId = usize;

#[derive(Debug, Clone)]
struct Vchan {
    /// `Some(ch)` pins every submission to that physical channel.
    pinned: Option<usize>,
    /// Cookies issued to this client, in submission order.
    cookies: Vec<Cookie>,
    /// Requests that failed after retry exhaustion.
    failures: u32,
    /// Quarantined clients get `Err` from every submission.
    quarantined: bool,
}

#[derive(Debug)]
pub struct MultiTenantDriver {
    phys: Vec<DmaDriver>,
    vchans: Vec<Vchan>,
    next_cookie: Cookie,
    /// Outstanding work: (cookie, physical channel, payload bytes).
    outstanding: Vec<(Cookie, usize, u64)>,
    completed: Vec<Cookie>,
    callback_cursor: usize,
    /// Failed requests of a vchan before it is quarantined;
    /// 0 disables quarantine.
    quarantine_after: u32,
    failed: Vec<Cookie>,
    failed_cursor: usize,
}

impl MultiTenantDriver {
    /// One [`DmaDriver`] per physical channel; the descriptor pool is
    /// split evenly (descriptor-aligned) between them.
    pub fn new(channels: usize, pool_base: u64, pool_size: u64, max_chains: usize) -> Self {
        assert!(channels >= 1, "at least one physical channel");
        let slice = pool_size / channels as u64 / DESC_BYTES * DESC_BYTES;
        let phys = (0..channels)
            .map(|ch| {
                DmaDriver::new(pool_base + ch as u64 * slice, slice, max_chains).on_channel(ch)
            })
            .collect();
        Self {
            phys,
            vchans: Vec::new(),
            next_cookie: 1,
            outstanding: Vec::new(),
            completed: Vec::new(),
            callback_cursor: 0,
            quarantine_after: 0,
            failed: Vec::new(),
            failed_cursor: 0,
        }
    }

    /// Install `policy` on every physical channel's driver, so a
    /// faulted chain is reset-and-resubmitted up to the policy's cap
    /// before its cookies surface as failed.
    pub fn with_retry(mut self, policy: RetryPolicy) -> Self {
        for d in &mut self.phys {
            d.retry = policy;
        }
        self
    }

    /// Quarantine a vchan once `n` of its requests have failed
    /// (post-retry).  `n = 0` (the default) disables quarantine.
    pub fn with_quarantine(mut self, n: u32) -> Self {
        self.quarantine_after = n;
        self
    }

    pub fn num_channels(&self) -> usize {
        self.phys.len()
    }

    /// Open a client submission queue with least-loaded placement.
    pub fn open(&mut self) -> VchanId {
        self.vchans.push(Vchan {
            pinned: None,
            cookies: Vec::new(),
            failures: 0,
            quarantined: false,
        });
        self.vchans.len() - 1
    }

    /// Open a client submission queue pinned to physical channel `ch`.
    pub fn open_pinned(&mut self, ch: usize) -> Result<VchanId> {
        if ch >= self.phys.len() {
            return Err(Error::Driver(format!(
                "cannot pin to channel {ch}: only {} channels",
                self.phys.len()
            )));
        }
        self.vchans.push(Vchan {
            pinned: Some(ch),
            cookies: Vec::new(),
            failures: 0,
            quarantined: false,
        });
        Ok(self.vchans.len() - 1)
    }

    /// Outstanding payload bytes currently placed on channel `ch`.
    pub fn channel_load(&self, ch: usize) -> u64 {
        self.outstanding.iter().filter(|&&(_, c, _)| c == ch).map(|&(_, _, b)| b).sum()
    }

    /// prep + submit in one step: place the transfer, build its
    /// descriptor list on the chosen channel's pool, and commit it.
    /// Returns the client-visible cookie (globally monotone).
    ///
    /// Unpinned placement prefers the least-loaded channel but falls
    /// back across the others (in load order) when a channel's pool
    /// slice is exhausted — outstanding bytes say nothing about
    /// descriptor headroom.  Pinned submissions fail like a dedicated
    /// channel would.
    pub fn submit(&mut self, vchan: VchanId, dst: u64, src: u64, len: u64) -> Result<Cookie> {
        self.submit_sg(vchan, &[(dst, src, len)])
    }

    /// Scatter-gather submit: place a guest-virtual `(dst, src, len)`
    /// list (e.g. the output of [`super::DmaMapper::dma_map_sg`]) as
    /// one transaction, with the same placement/fallback policy as
    /// [`submit`](Self::submit).
    pub fn submit_sg(&mut self, vchan: VchanId, sg: &[(u64, u64, u64)]) -> Result<Cookie> {
        let total: u64 = sg.iter().map(|&(_, _, len)| len).sum();
        self.place_and_commit(vchan, total, |drv| drv.prep_sg(sg))
    }

    /// ND-affine submit: one descriptor moving
    /// `row_bytes * nd.total_rows()` bytes as strided rows, placed with
    /// the same policy as [`submit`](Self::submit).  Addresses may be
    /// IOVAs; the IOMMU translates each row's pages in flight.
    pub fn submit_nd(
        &mut self,
        vchan: VchanId,
        dst: u64,
        src: u64,
        row_bytes: u32,
        nd: NdExt,
    ) -> Result<Cookie> {
        let total = nd.total_bytes_of(row_bytes);
        self.place_and_commit(vchan, total, |drv| drv.prep_nd(dst, src, row_bytes, nd))
    }

    /// Shared placement/commit path: try each candidate channel's pool
    /// in placement order, stamp the globally monotone cookie, commit.
    fn place_and_commit(
        &mut self,
        vchan: VchanId,
        total: u64,
        mut prep: impl FnMut(&mut DmaDriver) -> Result<super::dmaengine::Tx>,
    ) -> Result<Cookie> {
        if self.vchans[vchan].quarantined {
            return Err(Error::Driver(format!(
                "vchan {vchan} is quarantined after {} failed requests",
                self.vchans[vchan].failures
            )));
        }
        let candidates = self.placement_order(vchan);
        let mut last_err = None;
        for ch in candidates {
            match prep(&mut self.phys[ch]) {
                Ok(mut tx) => {
                    let cookie = self.next_cookie;
                    self.next_cookie += 1;
                    tx.cookie = cookie;
                    self.phys[ch].tx_submit(tx);
                    self.vchans[vchan].cookies.push(cookie);
                    self.outstanding.push((cookie, ch, total));
                    return Ok(cookie);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.expect("at least one candidate channel"))
    }

    /// Candidate physical channels for a submission from `vchan`, in
    /// placement order (pin, or least-loaded with fallback).
    fn placement_order(&self, vchan: VchanId) -> Vec<usize> {
        match self.vchans[vchan].pinned {
            Some(ch) => vec![ch],
            None => {
                let mut load = vec![0u64; self.phys.len()];
                for &(_, ch, bytes) in &self.outstanding {
                    load[ch] += bytes;
                }
                let mut order: Vec<usize> = (0..self.phys.len()).collect();
                order.sort_by_key(|&i| (load[i], i));
                order
            }
        }
    }

    /// `issue_pending` on every physical channel (each seals its own
    /// committed transactions into a chain on its banked CSR).
    pub fn issue_pending<C: Controller>(&mut self, sys: &mut System<C>, now: Cycle) {
        for d in &mut self.phys {
            d.issue_pending(sys, now);
        }
    }

    /// Shared interrupt handler: scan every channel's chains, promote
    /// stored chains, and collect completion callbacks.
    pub fn irq_handler<C: Controller>(&mut self, sys: &mut System<C>, now: Cycle) {
        for d in &mut self.phys {
            d.irq_handler(sys, now);
        }
        let mut newly = Vec::new();
        for d in &mut self.phys {
            newly.extend(d.take_completed());
        }
        if !newly.is_empty() {
            // One sweep over the outstanding set, not one per cookie.
            let done: std::collections::BTreeSet<Cookie> = newly.iter().copied().collect();
            self.outstanding.retain(|&(c, _, _)| !done.contains(&c));
            self.completed.extend(newly);
        }
        let mut newly_failed = Vec::new();
        for d in &mut self.phys {
            newly_failed.extend(d.take_failed());
        }
        if !newly_failed.is_empty() {
            // Failed work will never complete: stop counting it as
            // load, charge the owning vchan, and quarantine repeat
            // offenders.
            let dead: std::collections::BTreeSet<Cookie> = newly_failed.iter().copied().collect();
            self.outstanding.retain(|&(c, _, _)| !dead.contains(&c));
            for &cookie in &newly_failed {
                if let Some(v) = self.vchans.iter_mut().find(|v| v.cookies.contains(&cookie)) {
                    v.failures += 1;
                    if self.quarantine_after > 0 && v.failures >= self.quarantine_after {
                        v.quarantined = true;
                    }
                }
            }
            self.failed.extend(newly_failed);
        }
    }

    pub fn is_complete(&self, cookie: Cookie) -> bool {
        self.completed.contains(&cookie)
    }

    /// Completion callbacks fired since the last call.
    pub fn take_completed(&mut self) -> Vec<Cookie> {
        let new = self.completed[self.callback_cursor..].to_vec();
        self.callback_cursor = self.completed.len();
        new
    }

    /// Did `cookie` fail after retry exhaustion on its channel?
    pub fn is_failed(&self, cookie: Cookie) -> bool {
        self.failed.contains(&cookie)
    }

    /// Failure callbacks fired since the last call.
    pub fn take_failed(&mut self) -> Vec<Cookie> {
        let new = self.failed[self.failed_cursor..].to_vec();
        self.failed_cursor = self.failed.len();
        new
    }

    /// Is this client quarantined (all submissions rejected)?
    pub fn is_quarantined(&self, vchan: VchanId) -> bool {
        self.vchans[vchan].quarantined
    }

    /// Cookies issued to `vchan`, in submission order.
    pub fn cookies_of(&self, vchan: VchanId) -> &[Cookie] {
        &self.vchans[vchan].cookies
    }

    pub fn active_chains(&self) -> usize {
        self.phys.iter().map(DmaDriver::active_chains).sum()
    }

    pub fn stored_chains(&self) -> usize {
        self.phys.iter().map(DmaDriver::stored_chains).sum()
    }

    pub fn phys_driver(&self, ch: usize) -> &DmaDriver {
        &self.phys[ch]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::map;

    fn mt(channels: usize) -> MultiTenantDriver {
        MultiTenantDriver::new(channels, map::DESC_BASE, map::DESC_SIZE, 2)
    }

    #[test]
    fn pool_is_split_descriptor_aligned() {
        let d = MultiTenantDriver::new(3, 0x1000, 1000, 1);
        // 1000 / 3 = 333 -> floored to 320 (10 descriptors) per channel.
        assert_eq!(d.num_channels(), 3);
        let c1 = d.phys_driver(1);
        assert_eq!(c1.channel(), 1);
    }

    #[test]
    fn least_loaded_placement_balances_bytes() {
        let mut d = mt(2);
        let a = d.open();
        // First submit: both empty -> channel 0.
        d.submit(a, map::DST_BASE, map::SRC_BASE, 4096).unwrap();
        assert_eq!(d.channel_load(0), 4096);
        assert_eq!(d.channel_load(1), 0);
        // Second: channel 1 is now the least loaded.
        d.submit(a, map::DST_BASE + 8192, map::SRC_BASE, 1024).unwrap();
        assert_eq!(d.channel_load(1), 1024);
        // Third: channel 1 still lighter (1024 < 4096).
        d.submit(a, map::DST_BASE + 16384, map::SRC_BASE, 512).unwrap();
        assert_eq!(d.channel_load(1), 1536);
    }

    #[test]
    fn pinned_vchan_always_lands_on_its_channel() {
        let mut d = mt(2);
        let v = d.open_pinned(1).unwrap();
        for i in 0..4u64 {
            d.submit(v, map::DST_BASE + i * 4096, map::SRC_BASE, 4096).unwrap();
        }
        assert_eq!(d.channel_load(0), 0);
        assert_eq!(d.channel_load(1), 4 * 4096);
        assert!(d.open_pinned(7).is_err(), "pin beyond channel count");
    }

    #[test]
    fn cookies_are_globally_monotone_per_client() {
        let mut d = mt(2);
        let a = d.open();
        let b = d.open_pinned(1).unwrap();
        for i in 0..5u64 {
            d.submit(a, map::DST_BASE + i * 8192, map::SRC_BASE, 256).unwrap();
            d.submit(b, map::DST_BASE + 0x40000 + i * 8192, map::SRC_BASE, 256).unwrap();
        }
        for v in [a, b] {
            let cs = d.cookies_of(v);
            assert_eq!(cs.len(), 5);
            assert!(cs.windows(2).all(|w| w[1] > w[0]), "monotone cookies: {cs:?}");
        }
        // Global uniqueness across clients.
        let mut all: Vec<Cookie> =
            d.cookies_of(a).iter().chain(d.cookies_of(b)).copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 10);
    }

    #[test]
    fn unpinned_submit_falls_back_across_exhausted_pool_slices() {
        // 2 descriptors per channel slice.
        let mut d = MultiTenantDriver::new(2, map::DESC_BASE, 4 * DESC_BYTES, 1);
        let pinned = d.open_pinned(1).unwrap();
        d.submit(pinned, map::DST_BASE + 0x10000, map::SRC_BASE, 1024).unwrap();
        let v = d.open();
        // Channel 0 is least-loaded; two submits fill its pool slice.
        d.submit(v, map::DST_BASE, map::SRC_BASE, 64).unwrap();
        d.submit(v, map::DST_BASE + 0x1000, map::SRC_BASE, 64).unwrap();
        assert_eq!(d.channel_load(0), 128);
        // Channel 0 is still least-loaded but its slice is exhausted:
        // the submit must fall back to channel 1, not fail.
        d.submit(v, map::DST_BASE + 0x2000, map::SRC_BASE, 64).unwrap();
        assert_eq!(d.channel_load(1), 1024 + 64);
        // Every slice full -> a clean driver error.
        let err = d.submit(v, map::DST_BASE + 0x3000, map::SRC_BASE, 64);
        assert!(matches!(err, Err(Error::Driver(_))));
    }

    #[test]
    fn submit_nd_places_by_row_payload_and_counts_load() {
        let mut d = mt(2);
        let a = d.open();
        // 16 rows x 64 B = 1 KiB of outstanding payload on channel 0.
        let nd = NdExt { reps: [16, 1], src_stride: [256, 0], dst_stride: [64, 0] };
        let c0 = d.submit_nd(a, map::DST_BASE, map::SRC_BASE, 64, nd).unwrap();
        assert_eq!(d.channel_load(0), 16 * 64);
        assert_eq!(d.channel_load(1), 0);
        // Next submit lands on the now-lighter channel 1.
        let c1 = d.submit(a, map::DST_BASE + 0x10000, map::SRC_BASE, 128).unwrap();
        assert_eq!(d.channel_load(1), 128);
        assert!(c1 > c0, "cookies stay globally monotone across prep kinds");
    }

    #[test]
    fn repeatedly_faulting_vchan_is_quarantined_while_others_flow() {
        use crate::dmac::{DmacConfig, MultiChannel};
        use crate::mem::backdoor::fill_pattern;
        use crate::mem::{FaultConfig, LatencyProfile};
        use crate::soc::Soc;

        // One client's source window decode-errors on every access (an
        // unbacked IOVA range): its requests exhaust the retry policy
        // and fail, and after two failures the vchan is quarantined —
        // while the healthy client keeps completing on its channel.
        let bad_src = map::SRC_BASE + 0x2000;
        let cfg = DmacConfig::speculation()
            .with_faults(FaultConfig::seeded(9).with_decerr_window(bad_src, bad_src + 0x1000));
        let mut soc = Soc::new(LatencyProfile::Ddr3, MultiChannel::uniform(cfg, 2));
        fill_pattern(&mut soc.sys.mem, map::SRC_BASE, 1024, 0xBAD);
        let mut d = MultiTenantDriver::new(2, map::DESC_BASE, map::DESC_SIZE, 2)
            .with_retry(crate::driver::RetryPolicy::bounded(1, 16))
            .with_quarantine(2);
        let healthy = d.open_pinned(0).unwrap();
        let sick = d.open_pinned(1).unwrap();
        let good = d.submit(healthy, map::DST_BASE, map::SRC_BASE, 1024).unwrap();
        let bad_a = d.submit(sick, map::DST_BASE + 0x10000, bad_src, 512).unwrap();
        let bad_b = d.submit(sick, map::DST_BASE + 0x20000, bad_src + 0x200, 512).unwrap();
        d.issue_pending(&mut soc.sys, 0);
        soc.run(|sys, _cpu, now| d.irq_handler(sys, now)).unwrap();
        assert!(d.is_complete(good));
        assert!(d.is_failed(bad_a) && d.is_failed(bad_b));
        assert_eq!(d.take_failed(), vec![bad_a, bad_b]);
        assert!(d.is_quarantined(sick));
        assert!(!d.is_quarantined(healthy));
        assert_eq!(d.channel_load(1), 0, "failed work no longer counts as load");
        // The quarantined client is cut off; the healthy one continues.
        let refused = d.submit(sick, map::DST_BASE + 0x30000, map::SRC_BASE, 64);
        assert!(matches!(refused, Err(Error::Driver(_))));
        let again = d.submit(healthy, map::DST_BASE + 0x40000, map::SRC_BASE, 64).unwrap();
        let now = soc.now();
        d.issue_pending(&mut soc.sys, now);
        soc.run(|sys, _cpu, now| d.irq_handler(sys, now)).unwrap();
        assert!(d.is_complete(again));
    }

    #[test]
    fn exhausted_channel_pool_is_a_driver_error() {
        // 2 descriptors per channel.
        let mut d = MultiTenantDriver::new(2, map::DESC_BASE, 4 * DESC_BYTES, 1);
        let v = d.open_pinned(0).unwrap();
        assert!(d.submit(v, map::DST_BASE, map::SRC_BASE, 64).is_ok());
        assert!(d.submit(v, map::DST_BASE + 4096, map::SRC_BASE, 64).is_ok());
        let err = d.submit(v, map::DST_BASE + 8192, map::SRC_BASE, 64);
        assert!(matches!(err, Err(Error::Driver(_))));
    }
}
