//! The dmaengine-style *memcpy* driver state machine.

use super::retry::RetryPolicy;
use crate::dmac::descriptor::{error_status, is_completed, NdExt, ND_EXT_BYTES};
use crate::dmac::{Controller, Descriptor, DESC_BYTES, END_OF_CHAIN};
use crate::sim::Cycle;
use crate::tb::System;
use crate::{Error, Result};
use std::collections::VecDeque;

/// Completion cookie, exactly like dmaengine's monotonically
/// increasing `dma_cookie_t`.
pub type Cookie = u64;

/// A prepared-but-not-committed transaction.
#[derive(Debug, Clone)]
pub struct Tx {
    pub cookie: Cookie,
    /// (descriptor address, descriptor) — ≥1; only the last one may
    /// carry the IRQ flag once the chain is sealed.
    pub descs: Vec<(u64, Descriptor)>,
}

/// A chain scheduled (or queued) on the hardware.
#[derive(Debug, Clone)]
struct Chain {
    head: u64,
    last_desc: u64,
    cookies: Vec<Cookie>,
    /// The sealed descriptor list, kept so a failed chain can be
    /// rewritten (error stamps cleared) and resubmitted.
    descs: Vec<(u64, Descriptor)>,
    /// Resubmissions so far (bounded by the driver's [`RetryPolicy`]).
    attempts: u32,
}

#[derive(Debug)]
pub struct DmaDriver {
    /// Maximum chains allowed on the DMAC at once (§II-E step 3).
    pub max_chains: usize,
    /// Descriptor split size: transfers longer than this are chained
    /// over multiple descriptors (hardware max is 4 GiB; the driver
    /// uses 1 GiB chunks like the kernel's `dma_get_max_seg_size`).
    pub max_seg_bytes: u64,
    /// Physical DMAC channel this driver instance launches on (banked
    /// CSR; 0 on single-channel systems).
    channel: usize,
    pool_base: u64,
    pool_size: u64,
    pool_cursor: u64,
    /// Committed transactions awaiting `issue_pending` (FIFO).
    building: Vec<Tx>,
    /// Chains stored because `max_chains` were already active.
    stored: VecDeque<Chain>,
    active: Vec<Chain>,
    next_cookie: Cookie,
    completed: Vec<Cookie>,
    /// Cursor into `completed` for callback delivery (`take_completed`
    /// returns only the cookies completed since the previous call,
    /// while `is_complete` remains a stable status query).
    callback_cursor: usize,
    pub irqs_handled: u64,
    /// Channel-error recovery policy; [`RetryPolicy::none`] fails a
    /// chain on its first error.
    pub retry: RetryPolicy,
    /// Cookies whose chain errored and exhausted the retry budget.
    failed: Vec<Cookie>,
    failed_cursor: usize,
    /// Channel resets issued by the recovery path.
    pub resets_issued: u64,
    /// Chain resubmissions scheduled by the recovery path.
    pub retries_scheduled: u64,
}

impl DmaDriver {
    pub fn new(pool_base: u64, pool_size: u64, max_chains: usize) -> Self {
        Self {
            max_chains: max_chains.max(1),
            max_seg_bytes: 1 << 30,
            channel: 0,
            pool_base,
            pool_size,
            pool_cursor: 0,
            building: Vec::new(),
            stored: VecDeque::new(),
            active: Vec::new(),
            next_cookie: 1,
            completed: Vec::new(),
            callback_cursor: 0,
            irqs_handled: 0,
            retry: RetryPolicy::none(),
            failed: Vec::new(),
            failed_cursor: 0,
            resets_issued: 0,
            retries_scheduled: 0,
        }
    }

    /// Bind this driver instance to physical channel `ch` (its CSR
    /// writes and promoted chains launch there).
    pub fn on_channel(mut self, ch: usize) -> Self {
        self.channel = ch;
        self
    }

    /// Enable bounded reset-and-resubmit recovery for errored chains.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn channel(&self) -> usize {
        self.channel
    }

    fn alloc_desc(&mut self) -> Result<u64> {
        self.alloc_bytes(DESC_BYTES)
    }

    /// Allocate `bytes` contiguous pool bytes (an ND descriptor needs
    /// head + extension word in one 64-byte span).
    fn alloc_bytes(&mut self, bytes: u64) -> Result<u64> {
        if self.pool_cursor + bytes > self.pool_size {
            return Err(Error::Driver("descriptor pool exhausted".into()));
        }
        let addr = self.pool_base + self.pool_cursor;
        self.pool_cursor += bytes;
        Ok(addr)
    }

    /// `device_prep_dma_memcpy`: build the descriptor list for one
    /// client transfer — the one-element special case of
    /// [`prep_sg`](Self::prep_sg).
    pub fn prep_memcpy(&mut self, dst: u64, src: u64, len: u64) -> Result<Tx> {
        self.prep_sg(&[(dst, src, len)])
    }

    /// `device_prep_dma_sg`: one transaction covering a guest-virtual
    /// scatter-gather list of `(dst, src, len)` triples — one or more
    /// descriptors per element (long elements split over
    /// `max_seg_bytes`), one completion cookie for the whole list.
    /// Addresses may be IOVAs produced by [`super::DmaMapper`]; the
    /// IOMMU translates them in flight.  A prep that exhausts the pool
    /// mid-list frees everything it allocated (the failed transaction
    /// must not leak descriptors).
    pub fn prep_sg(&mut self, sg: &[(u64, u64, u64)]) -> Result<Tx> {
        if sg.is_empty() || sg.iter().any(|&(_, _, len)| len == 0) {
            return Err(Error::Driver("empty or zero-length sg element".into()));
        }
        let cookie = self.next_cookie;
        self.next_cookie += 1;
        let pool_checkpoint = self.pool_cursor;
        let mut descs = Vec::new();
        for &(dst, src, len) in sg {
            let mut off = 0u64;
            while off < len {
                let seg = (len - off).min(self.max_seg_bytes).min(u32::MAX as u64 & !63);
                let addr = match self.alloc_desc() {
                    Ok(addr) => addr,
                    Err(e) => {
                        self.pool_cursor = pool_checkpoint;
                        return Err(e);
                    }
                };
                descs.push((addr, Descriptor::new(src + off, dst + off, seg as u32)));
                off += seg;
            }
        }
        Ok(Tx { cookie, descs })
    }

    /// `device_prep_dma_nd`: one ND-affine descriptor moving
    /// `row_bytes * nd.total_rows()` bytes as strided rows — the
    /// layout-flexible equivalent of a [`prep_sg`](Self::prep_sg) list
    /// with one element per row, at a fraction of the descriptor
    /// traffic.  Allocates a contiguous head + extension span from the
    /// pool.
    pub fn prep_nd(&mut self, dst: u64, src: u64, row_bytes: u32, nd: NdExt) -> Result<Tx> {
        if row_bytes == 0 {
            return Err(Error::Driver("zero-length ND row".into()));
        }
        if nd.reps.iter().any(|&r| r == 0) {
            return Err(Error::Driver("ND level with zero repetitions".into()));
        }
        if row_bytes as u128 * nd.total_rows() as u128 > u64::MAX as u128 {
            return Err(Error::Driver("ND transfer exceeds the 64-bit byte space".into()));
        }
        let cookie = self.next_cookie;
        self.next_cookie += 1;
        let addr = self.alloc_bytes(DESC_BYTES + ND_EXT_BYTES)?;
        let d = Descriptor::new(src, dst, row_bytes).with_nd_levels(nd);
        Ok(Tx { cookie, descs: vec![(addr, d)] })
    }

    /// `tx_submit`: commit the transaction to the chain being built
    /// (FIFO order).
    pub fn tx_submit(&mut self, tx: Tx) -> Cookie {
        let cookie = tx.cookie;
        self.building.push(tx);
        cookie
    }

    /// `issue_pending`: seal the committed transactions into one
    /// chain, write the descriptors into (simulated) memory and launch
    /// it — or store it if `max_chains` are already running.
    pub fn issue_pending<C: Controller>(&mut self, sys: &mut System<C>, now: Cycle) {
        if self.building.is_empty() {
            return;
        }
        let txs = std::mem::take(&mut self.building);
        let cookies: Vec<Cookie> = txs.iter().map(|t| t.cookie).collect();
        let mut flat: Vec<(u64, Descriptor)> =
            txs.into_iter().flat_map(|t| t.descs.into_iter()).collect();
        let n = flat.len();
        for i in 0..n {
            let next = if i + 1 < n { flat[i + 1].0 } else { END_OF_CHAIN };
            flat[i].1.next = next;
        }
        // Only the last descriptor of the chain signals (§II-E).
        flat[n - 1].1 = flat[n - 1].1.with_irq();
        write_chain(sys, &flat);
        let chain = Chain {
            head: flat[0].0,
            last_desc: flat[n - 1].0,
            cookies,
            descs: flat,
            attempts: 0,
        };
        if self.active.len() < self.max_chains {
            sys.schedule_launch_on(now + 1, self.channel, chain.head);
            self.active.push(chain);
        } else {
            self.stored.push_back(chain);
        }
    }

    /// The interrupt handler: detect completed chains via the
    /// in-memory completion stamp of their last descriptor, fire
    /// callbacks, recover errored chains (reset + bounded resubmit),
    /// and schedule stored chains.
    ///
    /// Registered for both the completion IRQ and the channel error
    /// IRQ — like a shared Linux ISR, the source selects no distinct
    /// code path; the handler re-scans stamps and the error CSR.
    pub fn irq_handler<C: Controller>(&mut self, sys: &mut System<C>, now: Cycle) {
        self.irqs_handled += 1;
        // A halted channel froze everything still queued on it: every
        // incomplete active chain must be rewritten and relaunched
        // after the reset, not just the one named by the error CSR.
        let halted = sys.ctrl.error_csr(self.channel).is_some();
        let mut to_recover = Vec::new();
        let mut still_active = Vec::new();
        for chain in self.active.drain(..) {
            let errored =
                chain.descs.iter().any(|&(addr, _)| error_status(&sys.mem, addr).is_some());
            if !errored && is_completed(&sys.mem, chain.last_desc) {
                self.completed.extend(chain.cookies.iter().copied());
            } else if errored || halted {
                to_recover.push(chain);
            } else {
                still_active.push(chain);
            }
        }
        self.active = still_active;
        if halted {
            sys.schedule_reset(now + 1, self.channel);
            self.resets_issued += 1;
        }
        for mut chain in to_recover {
            if self.retry.allows(chain.attempts) {
                // Rewrite the whole chain: clears error stamps and the
                // completion stamps of already-finished members (a
                // memcpy re-run is idempotent), then relaunch behind
                // the reset with exponential backoff.
                let delay = 2 + self.retry.backoff(chain.attempts);
                chain.attempts += 1;
                self.retries_scheduled += 1;
                write_chain(sys, &chain.descs);
                sys.schedule_launch_on(now + delay, self.channel, chain.head);
                self.active.push(chain);
            } else {
                self.failed.extend(chain.cookies.iter().copied());
            }
        }
        while self.active.len() < self.max_chains {
            match self.stored.pop_front() {
                Some(chain) => {
                    sys.schedule_launch_on(now + 1, self.channel, chain.head);
                    self.active.push(chain);
                }
                None => break,
            }
        }
    }

    /// dmaengine `dma_async_is_tx_complete` equivalent.
    pub fn is_complete(&self, cookie: Cookie) -> bool {
        self.completed.contains(&cookie)
    }

    /// The transaction errored and exhausted its retry budget
    /// (dmaengine's `DMA_ERROR` cookie status).
    pub fn is_failed(&self, cookie: Cookie) -> bool {
        self.failed.contains(&cookie)
    }

    /// Completion callbacks fired since the last call.
    pub fn take_completed(&mut self) -> Vec<Cookie> {
        let new = self.completed[self.callback_cursor..].to_vec();
        self.callback_cursor = self.completed.len();
        new
    }

    /// Failure callbacks fired since the last call.
    pub fn take_failed(&mut self) -> Vec<Cookie> {
        let new = self.failed[self.failed_cursor..].to_vec();
        self.failed_cursor = self.failed.len();
        new
    }

    pub fn active_chains(&self) -> usize {
        self.active.len()
    }

    pub fn stored_chains(&self) -> usize {
        self.stored.len()
    }

    /// Free all descriptors (client teardown).
    pub fn reset_pool(&mut self) {
        self.pool_cursor = 0;
    }
}

/// Write a sealed descriptor list into simulated memory (initial
/// submission and retry rewrites share this path).
fn write_chain<C: Controller>(sys: &mut System<C>, descs: &[(u64, Descriptor)]) {
    for (addr, d) in descs {
        sys.mem.backdoor_write(*addr, &d.to_bytes());
        if let Some(nd) = d.nd {
            sys.mem.backdoor_write(*addr + DESC_BYTES, &nd.to_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmac::{Dmac, DmacConfig};
    use crate::mem::backdoor::fill_pattern;
    use crate::mem::LatencyProfile;
    use crate::soc::Soc;
    use crate::workload::map;

    fn driver() -> DmaDriver {
        DmaDriver::new(map::DESC_BASE, map::DESC_SIZE, 2)
    }

    #[test]
    fn prep_splits_long_transfers() {
        let mut d = driver();
        d.max_seg_bytes = 4096;
        let tx = d.prep_memcpy(map::DST_BASE, map::SRC_BASE, 10_000).unwrap();
        assert_eq!(tx.descs.len(), 3);
        let total: u64 = tx.descs.iter().map(|(_, d)| d.length as u64).sum();
        assert_eq!(total, 10_000);
        // Segments are contiguous.
        assert_eq!(tx.descs[1].1.source, map::SRC_BASE + 4096);
        assert_eq!(tx.descs[1].1.destination, map::DST_BASE + 4096);
    }

    #[test]
    fn zero_length_rejected() {
        assert!(driver().prep_memcpy(0, 0, 0).is_err());
    }

    #[test]
    fn pool_exhaustion_is_an_error() {
        let mut d = DmaDriver::new(map::DESC_BASE, 64, 1); // room for 2
        assert!(d.prep_memcpy(1 << 20, 0, 64).is_ok());
        assert!(d.prep_memcpy(1 << 20, 0, 64).is_ok());
        assert!(d.prep_memcpy(1 << 20, 0, 64).is_err());
        d.reset_pool();
        assert!(d.prep_memcpy(1 << 20, 0, 64).is_ok());
    }

    #[test]
    fn full_memcpy_round_trip_through_the_soc() {
        let mut soc = Soc::new(LatencyProfile::Ddr3, Dmac::new(DmacConfig::speculation()));
        let mut drv = driver();
        fill_pattern(&mut soc.sys.mem, map::SRC_BASE, 8192, 9);
        let tx = drv.prep_memcpy(map::DST_BASE, map::SRC_BASE, 8192).unwrap();
        let cookie = drv.tx_submit(tx);
        drv.issue_pending(&mut soc.sys, 0);
        assert_eq!(drv.active_chains(), 1);
        let mut drv_cell = drv;
        let stats = soc
            .run(|sys, _cpu, now| drv_cell.irq_handler(sys, now))
            .unwrap();
        assert!(stats.completions.len() >= 1);
        assert!(drv_cell.is_complete(cookie));
        assert_eq!(drv_cell.active_chains(), 0);
        let src = soc.sys.mem.backdoor_read(map::SRC_BASE, 8192).to_vec();
        let dst = soc.sys.mem.backdoor_read(map::DST_BASE, 8192).to_vec();
        assert_eq!(src, dst);
    }

    #[test]
    fn prep_nd_moves_strided_rows_through_the_soc() {
        let mut soc = Soc::new(LatencyProfile::Ddr3, Dmac::new(DmacConfig::speculation()));
        let mut drv = driver();
        for r in 0..8u64 {
            fill_pattern(&mut soc.sys.mem, map::SRC_BASE + r * 1024, 256, r as u32 + 1);
        }
        // 8 rows of 256 B: sparse source (stride 1 KiB), packed dest.
        let nd = NdExt { reps: [8, 1], src_stride: [1024, 0], dst_stride: [256, 0] };
        let tx = drv.prep_nd(map::DST_BASE, map::SRC_BASE, 256, nd).unwrap();
        assert_eq!(tx.descs.len(), 1, "one descriptor for the whole gather");
        let cookie = drv.tx_submit(tx);
        drv.issue_pending(&mut soc.sys, 0);
        let mut drv_cell = drv;
        let stats = soc.run(|sys, _cpu, now| drv_cell.irq_handler(sys, now)).unwrap();
        assert!(drv_cell.is_complete(cookie));
        assert_eq!(stats.nd_descriptors, 1);
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(stats.total_bytes(), 8 * 256);
        for r in 0..8u64 {
            assert_eq!(
                soc.sys.mem.backdoor_read(map::SRC_BASE + r * 1024, 256).to_vec(),
                soc.sys.mem.backdoor_read(map::DST_BASE + r * 256, 256).to_vec(),
                "row {r}"
            );
        }
    }

    #[test]
    fn prep_nd_validates_and_charges_two_pool_slots() {
        let mut d = DmaDriver::new(map::DESC_BASE, 64, 1); // one 64 B span
        assert!(d.prep_nd(0x1000, 0x2000, 0, NdExt::linear()).is_err());
        let mut bad = NdExt::linear();
        bad.reps[0] = 0;
        assert!(d.prep_nd(0x1000, 0x2000, 64, bad).is_err());
        assert!(d.prep_nd(0x1000, 0x2000, 64, NdExt::linear()).is_ok());
        assert!(
            d.prep_memcpy(0x1000, 0x2000, 64).is_err(),
            "head + extension consumed the whole pool"
        );
    }

    #[test]
    fn max_chains_defers_and_irq_handler_schedules_stored() {
        let mut soc = Soc::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        let mut drv = DmaDriver::new(map::DESC_BASE, map::DESC_SIZE, 1);
        fill_pattern(&mut soc.sys.mem, map::SRC_BASE, 4096, 3);
        let mut cookies = Vec::new();
        for i in 0..3u64 {
            let tx = drv
                .prep_memcpy(map::DST_BASE + i * 4096, map::SRC_BASE + i * 4096, 1024)
                .unwrap();
            cookies.push(drv.tx_submit(tx));
            drv.issue_pending(&mut soc.sys, 0);
        }
        assert_eq!(drv.active_chains(), 1);
        assert_eq!(drv.stored_chains(), 2);
        let mut drv_cell = drv;
        soc.run(|sys, _cpu, now| drv_cell.irq_handler(sys, now)).unwrap();
        for c in cookies {
            assert!(drv_cell.is_complete(c), "cookie {c}");
        }
        assert_eq!(drv_cell.stored_chains(), 0);
        assert_eq!(drv_cell.irqs_handled, 3);
    }

    #[test]
    fn fetch_fault_recovery_round_trip_through_the_soc() {
        use crate::mem::FaultConfig;
        // Exactly one SLVERR, landing on the first descriptor-fetch
        // beat: the channel halts, the error IRQ fires, and the driver
        // resets + resubmits to a now-clean bus.
        let cfg = DmacConfig::speculation()
            .with_faults(FaultConfig::seeded(5).with_read_slverr(1_000_000).with_max_faults(1))
            .with_watchdog(5000);
        let mut soc = Soc::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        let mut drv = driver().with_retry(crate::driver::RetryPolicy::bounded(3, 32));
        fill_pattern(&mut soc.sys.mem, map::SRC_BASE, 4096, 7);
        let tx = drv.prep_memcpy(map::DST_BASE, map::SRC_BASE, 4096).unwrap();
        let cookie = drv.tx_submit(tx);
        drv.issue_pending(&mut soc.sys, 0);
        let mut drv_cell = drv;
        let stats = soc.run(|sys, _cpu, now| drv_cell.irq_handler(sys, now)).unwrap();
        assert!(drv_cell.is_complete(cookie), "recovered after reset + resubmit");
        assert!(!drv_cell.is_failed(cookie));
        assert_eq!(drv_cell.resets_issued, 1);
        assert_eq!(drv_cell.retries_scheduled, 1);
        assert_eq!(stats.fault_halts, 1);
        assert_eq!(stats.channel_resets, 1);
        assert_eq!(
            soc.sys.mem.backdoor_read(map::SRC_BASE, 4096).to_vec(),
            soc.sys.mem.backdoor_read(map::DST_BASE, 4096).to_vec()
        );
    }

    #[test]
    fn persistent_decerr_exhausts_retries_and_fails_the_cookie() {
        use crate::mem::FaultConfig;
        // The source buffer sits in a DECERR hole that stays bad on
        // every retry: the bounded policy gives up and the cookie
        // fails without ever halting the channel.
        let cfg = DmacConfig::base().with_faults(
            FaultConfig::seeded(6).with_decerr_window(map::SRC_BASE, map::SRC_BASE + 0x1000),
        );
        let mut soc = Soc::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        let mut drv = driver().with_retry(crate::driver::RetryPolicy::bounded(2, 16));
        let tx = drv.prep_memcpy(map::DST_BASE, map::SRC_BASE, 256).unwrap();
        let cookie = drv.tx_submit(tx);
        drv.issue_pending(&mut soc.sys, 0);
        let mut drv_cell = drv;
        let stats = soc.run(|sys, _cpu, now| drv_cell.irq_handler(sys, now)).unwrap();
        assert!(drv_cell.is_failed(cookie));
        assert!(!drv_cell.is_complete(cookie));
        assert_eq!(drv_cell.take_failed(), vec![cookie]);
        assert_eq!(drv_cell.resets_issued, 0, "data errors never halt the channel");
        assert_eq!(drv_cell.retries_scheduled, 2);
        // Initial attempt + 2 retries, all aborted.
        assert_eq!(stats.aborted_transfers, 3);
    }

    #[test]
    fn issue_pending_batches_multiple_txs_into_one_chain() {
        let mut soc = Soc::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        let mut drv = driver();
        fill_pattern(&mut soc.sys.mem, map::SRC_BASE, 4096, 4);
        let a = drv.prep_memcpy(map::DST_BASE, map::SRC_BASE, 512).unwrap();
        let b = drv.prep_memcpy(map::DST_BASE + 4096, map::SRC_BASE + 512, 512).unwrap();
        drv.tx_submit(a);
        drv.tx_submit(b);
        drv.issue_pending(&mut soc.sys, 0);
        assert_eq!(drv.active_chains(), 1, "one chain for both txs");
        let mut drv_cell = drv;
        let stats = soc.run(|sys, _cpu, now| drv_cell.irq_handler(sys, now)).unwrap();
        // One IRQ for the whole chain (only last descriptor signals).
        assert_eq!(stats.irqs, 1);
        assert_eq!(stats.completions.len(), 2);
    }
}
