//! Bounded retry with deterministic backoff.
//!
//! Linux DMA drivers recover from channel errors by resetting the
//! channel and resubmitting the failed request a bounded number of
//! times (e.g. the dmaengine `device_terminate_all` + resubmit dance).
//! [`RetryPolicy`] captures that loop for the simulated drivers: a cap
//! on resubmissions per request and an exponential cycle-based backoff
//! between them.  Everything is integer cycle arithmetic — no wall
//! clock — so recovery schedules are bit-identical across runs and
//! schedulers.

/// Retry knobs shared by [`super::DmaDriver`], [`super::RingDriver`]
/// and [`super::MultiTenantDriver`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Maximum resubmissions per request; 0 = fail on first error.
    pub max_retries: u32,
    /// Base backoff in cycles; retry `n` waits `backoff_cycles << n`.
    pub backoff_cycles: u64,
}

impl RetryPolicy {
    /// No retries: the first error fails the request (the default).
    pub fn none() -> Self {
        Self { max_retries: 0, backoff_cycles: 0 }
    }

    /// Up to `max_retries` resubmissions with exponential backoff from
    /// `backoff_cycles`.
    pub fn bounded(max_retries: u32, backoff_cycles: u64) -> Self {
        Self { max_retries, backoff_cycles }
    }

    /// May a request that already failed `attempts` times go again?
    pub fn allows(&self, attempts: u32) -> bool {
        attempts < self.max_retries
    }

    /// Backoff before retry number `attempt` (0-based): exponential,
    /// with the shift clamped so pathological attempt counts cannot
    /// overflow the cycle space.
    pub fn backoff(&self, attempt: u32) -> u64 {
        self.backoff_cycles << attempt.min(16)
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_allows() {
        let p = RetryPolicy::none();
        assert!(!p.allows(0));
        assert_eq!(p.backoff(0), 0);
    }

    #[test]
    fn bounded_allows_up_to_the_cap() {
        let p = RetryPolicy::bounded(2, 100);
        assert!(p.allows(0));
        assert!(p.allows(1));
        assert!(!p.allows(2));
    }

    #[test]
    fn backoff_is_exponential_and_clamped() {
        let p = RetryPolicy::bounded(40, 16);
        assert_eq!(p.backoff(0), 16);
        assert_eq!(p.backoff(1), 32);
        assert_eq!(p.backoff(3), 128);
        assert_eq!(p.backoff(63), 16 << 16, "shift clamps at 16");
    }
}
