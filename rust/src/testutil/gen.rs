//! Shared randomized-workload generators for the property and stress
//! suites.
//!
//! Before the stress suite existed, `tests/properties.rs`,
//! `tests/iommu.rs` and `tests/nd.rs` each re-rolled their own
//! `random_chain`/`random_config`/`random_profile`; this module is the
//! single generator set they (and `tests/stress.rs`) now share, so a
//! distribution fix lands everywhere at once.

use super::SplitMix64;
use crate::dmac::{ChainBuilder, Descriptor, DmacConfig, IommuParams};
use crate::mem::{DramParams, LatencyProfile, MemBackend};
use crate::workload::map;

/// Transfer sizes the random chains draw from: byte-granular odd
/// sizes, bus-aligned sizes and whole-line multiples.
pub const CHAIN_SIZES: [u32; 7] = [1, 8, 17, 64, 100, 256, 1024];

/// Random race-free chain of at most `max_n` descriptors: unique
/// destination slots (no write/write races, so overlapped backend
/// execution equals sequential semantics), sources drawn from a
/// disjoint region, random sizes, and random — but monotone,
/// collision-free — descriptor placement that exercises both hits and
/// misses of the sequential prefetcher.  Returns the chain plus its
/// `(src, dst, size)` metadata.
pub fn random_chain_sized(
    rng: &mut SplitMix64,
    max_n: u64,
) -> (ChainBuilder, Vec<(u64, u64, u32)>) {
    let n = rng.range(2, max_n.clamp(2, 64)) as usize;
    let mut cb = ChainBuilder::new();
    let mut meta = Vec::new();
    let mut dst_slots: Vec<u64> = (0..64).collect();
    rng.shuffle(&mut dst_slots);
    let mut desc_addr = map::DESC_BASE;
    for i in 0..n {
        let size = *rng.pick(&CHAIN_SIZES);
        let src = map::SRC_BASE + rng.below(32) * 4096;
        let dst = map::DST_BASE + dst_slots[i] * 4096;
        let d = Descriptor::new(src, dst, size);
        let d = if i + 1 == n { d.with_irq() } else { d };
        cb.push_at(desc_addr, d);
        meta.push((src, dst, size));
        desc_addr += 32 * rng.range(1, 4);
    }
    (cb, meta)
}

/// [`random_chain_sized`] at the historical default of up to 40
/// descriptors.
pub fn random_chain(rng: &mut SplitMix64) -> (ChainBuilder, Vec<(u64, u64, u32)>) {
    random_chain_sized(rng, 40)
}

/// Random in-flight/prefetch configuration (Table I custom point).
pub fn random_config(rng: &mut SplitMix64) -> DmacConfig {
    let in_flight = rng.range(1, 32) as usize;
    let prefetch = rng.range(0, 32) as usize;
    DmacConfig::custom(in_flight, prefetch)
}

/// Random one-way memory latency across the paper's whole sweep range.
pub fn random_profile(rng: &mut SplitMix64) -> LatencyProfile {
    LatencyProfile::Custom(rng.range(1, 120) as u32)
}

/// Random banked-DRAM timing geometry, spanning tiny test shapes to
/// DDR3-like parameters (always legal: every field stays above the
/// floors `DramParams` itself enforces).
pub fn random_dram_params(rng: &mut SplitMix64) -> DramParams {
    let t_refi = if rng.chance(0.5) { 0 } else { rng.range(200, 4000) as u32 };
    DramParams {
        banks: 1 << rng.below(4),
        row_bytes: *rng.pick(&[256u32, 1024, 2048]),
        t_cas: rng.range(1, 8) as u32,
        t_rcd: rng.range(1, 8) as u32,
        t_rp: rng.range(1, 8) as u32,
        t_refi,
        t_rfc: if t_refi == 0 { 0 } else { rng.range(4, 60) as u32 },
        wq_watermark: rng.range(1, 24) as u32,
    }
}

/// Random memory timing backend: the default pipe half the time, a
/// random banked-DRAM geometry otherwise.
pub fn random_mem_backend(rng: &mut SplitMix64) -> MemBackend {
    if rng.chance(0.5) {
        MemBackend::Pipe
    } else {
        MemBackend::Dram(random_dram_params(rng))
    }
}

/// Random enabled SV39 translation stage with a small IOTLB.
pub fn random_iommu(rng: &mut SplitMix64) -> IommuParams {
    IommuParams::enabled(
        rng.range(1, 16) as usize,
        rng.range(1, 4) as usize,
        rng.chance(0.5),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    #[test]
    fn chains_are_race_free_and_in_bounds() {
        forall(25, |rng| {
            let (cb, meta) = random_chain(rng);
            assert_eq!(cb.len(), meta.len());
            assert!((2..=40).contains(&cb.len()));
            // Unique destination slots; arenas respected.
            let mut dsts: Vec<u64> = meta.iter().map(|&(_, d, _)| d).collect();
            dsts.sort_unstable();
            dsts.dedup();
            assert_eq!(dsts.len(), meta.len(), "destination slots must be unique");
            for &(src, dst, size) in &meta {
                assert!(src >= map::SRC_BASE && src + size as u64 <= map::DST_BASE);
                assert!(dst >= map::DST_BASE && dst + size as u64 <= map::ARENA_BASE);
            }
            // Monotone, collision-free descriptor placement.
            for w in cb.addrs().windows(2) {
                assert!(w[1] >= w[0] + 32);
            }
            // Only the last descriptor signals.
            let descs = cb.descriptors();
            assert!(descs[..descs.len() - 1].iter().all(|d| !d.irq_enabled()));
            assert!(descs.last().unwrap().irq_enabled());
        });
    }

    #[test]
    fn sized_chains_respect_the_cap() {
        forall(25, |rng| {
            let (cb, _) = random_chain_sized(rng, 6);
            assert!((2..=6).contains(&cb.len()));
        });
    }

    #[test]
    fn configs_and_profiles_stay_in_range() {
        forall(25, |rng| {
            let cfg = random_config(rng);
            assert!((1..=32).contains(&cfg.in_flight));
            assert!(cfg.prefetch <= 32);
            let LatencyProfile::Custom(l) = random_profile(rng) else {
                panic!("random_profile must produce a custom latency");
            };
            assert!((1..=120).contains(&l));
            let io = random_iommu(rng);
            assert!(io.enabled);
            assert!((1..=16).contains(&io.tlb_sets));
            assert!((1..=4).contains(&io.tlb_ways));
            let p = random_dram_params(rng);
            assert!([1, 2, 4, 8].contains(&p.banks));
            assert!([256, 1024, 2048].contains(&p.row_bytes));
            assert!((1..=8).contains(&p.t_cas));
            assert!(p.t_refi == 0 || (200..=4000).contains(&p.t_refi));
            assert!(p.t_refi > 0 || p.t_rfc == 0, "no refresh, no tRFC");
            assert!((1..=24).contains(&p.wq_watermark));
            assert!(matches!(
                random_mem_backend(rng),
                MemBackend::Pipe | MemBackend::Dram(_)
            ));
        });
    }
}
