//! Test utilities: a deterministic PRNG and a mini property-testing
//! framework.
//!
//! The offline build environment has neither `rand` nor `proptest`
//! vendored, so both are implemented here: [`SplitMix64`] (Steele et
//! al., public-domain mixing function) and [`forall`], a shrinking-free
//! property runner that reports the failing seed for reproduction.

pub mod gen;

/// SplitMix64: tiny, high-quality, deterministic PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift: unbiased enough for test workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Run `cases` random property checks.  On failure, panics with the
/// case's seed so the exact input can be replayed with
/// `forall_seeded(seed, …)`.
pub fn forall(cases: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xD1F0_57A7_E5EE_D000 ^ case;
        forall_seeded(seed, &mut prop);
    }
}

/// Run one property case with an explicit seed (replay helper).
pub fn forall_seeded(seed: u64, prop: &mut impl FnMut(&mut SplitMix64)) {
    let mut rng = SplitMix64::new(seed);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
    if let Err(e) = result {
        eprintln!("property failed for seed {seed:#x} — replay with forall_seeded({seed:#x}, ...)");
        std::panic::resume_unwind(e);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_stays_in_range() {
        let mut rng = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut rng = SplitMix64::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = rng.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn chance_rough_frequency() {
        let mut rng = SplitMix64::new(11);
        let hits = (0..100_000).filter(|_| rng.chance(0.25)).count();
        assert!((20_000..30_000).contains(&hits), "hits = {hits}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..32).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall(25, |_| n += 1);
        assert_eq!(n, 25);
    }
}
