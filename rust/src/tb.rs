//! The out-of-context testbench (paper Fig. 3).
//!
//! A latency-configurable memory system and a *launch unit* driving
//! random streams of descriptors: both DMAC manager interfaces share
//! the memory through a fair round-robin arbiter; descriptors are
//! pre-loaded through a backdoor, and transfers are launched via the
//! DMAC's CSR.  The testbench is generic over [`Controller`], so the
//! same harness evaluates our DMAC and the LogiCORE baseline.

use crate::axi::{ArbPolicy, Arbiter, BusMonitor, Crossbar, Port, XbarConfig};
use crate::dmac::{ChainBuilder, Controller};
use crate::mem::{LatencyProfile, Memory};
use crate::sim::trace::{TraceEvent, TraceRecord, Tracer};
use crate::sim::{Cycle, CycleBudget, EventHorizon, RunStats};
use std::collections::VecDeque;

/// Default simulated DRAM size: 16 MiB is enough for every paper sweep.
pub const DEFAULT_MEM_BYTES: usize = 16 << 20;

/// Fast loop: budget re-check interval in scheduler iterations.  The
/// per-cycle `CycleBudget::check` of the naive loop moved out of the
/// hot path — the fast loop checks at every event-horizon jump plus
/// once per this many single-cycle steps, which still bounds a
/// deadlocked (never-jumping) model.
const BUDGET_CHECK_MASK: u64 = 0xFFF;

/// One scheduled MMIO write of the launch unit.
#[derive(Debug, Clone, Copy)]
enum LaunchOp {
    /// CSR chain launch: the chain head address.
    Csr(u64),
    /// Submission-ring doorbell: the new free-running tail index.
    Doorbell(u64),
    /// Completion-ring consumer doorbell: the free-running head index.
    CqDoorbell(u64),
    /// Channel-reset CSR write: clear the sticky fault and drop queued
    /// work so a recovery driver can resubmit.
    Reset,
}

#[derive(Clone)]
pub struct System<C: Controller> {
    /// Controller-0 memory.  On the shared bus it is *the* memory; on
    /// a crossbar it is interleave slice 0 — but its byte image mirrors
    /// every controller (see `axi::crossbar`), so backdoor reads and
    /// chain loads keep working unchanged.
    pub mem: Memory,
    pub ctrl: C,
    pub monitor: BusMonitor,
    /// Launch unit schedule: (cycle, channel, MMIO write).
    launches: VecDeque<(Cycle, usize, LaunchOp)>,
    ar_arb: Arbiter,
    w_arb: Arbiter,
    /// Memory controllers 1..M of a crossbar system (empty on the
    /// shared bus and for a 1×1 crossbar).
    extra_mems: Vec<Memory>,
    /// The interconnect, when this system was built with
    /// [`System::with_crossbar`]; `None` selects the legacy shared-bus
    /// data path, bit for bit.
    xbar: Option<Crossbar>,
    /// One-shot flag: controller byte images are synchronized from
    /// `mem` on the first crossbar tick, after all backdoor pre-loads.
    xbar_synced: bool,
    now: Cycle,
    budget: CycleBudget,
    /// Fast-forward bookkeeping: jumps taken and dead cycles skipped.
    pub horizon: EventHorizon,
    /// IRQ edges observed (the PLIC in the SoC model; a counter here).
    pub irqs_seen: u64,
    /// Cumulative IRQ edges per channel (index = channel id; grown on
    /// first edge).  The SoC routes these to banked PLIC sources.
    pub irq_edges: Vec<u64>,
    /// Cumulative coalesced completion-ring IRQ edges per channel.
    /// The SoC routes these to the dedicated banked ring sources.
    pub ring_irq_edges: Vec<u64>,
    /// Cumulative IOMMU translation-fault edges per channel.  The SoC
    /// routes these to the dedicated banked fault sources.
    pub fault_edges: Vec<u64>,
    /// Cumulative channel error-IRQ edges per channel (descriptor-fetch
    /// faults, poisoned completions, watchdog timeouts).  The SoC
    /// routes these to the dedicated banked error sources.
    pub error_irq_edges: Vec<u64>,
    /// First AR issue cycle per port (Table IV `i-rf` / `rf-rb`).
    pub first_ar: Vec<(Port, Cycle)>,
    /// First payload R-beat delivery cycle (Table IV `r-w`).
    pub first_payload_r: Option<Cycle>,
    /// First payload W-beat issue cycle (Table IV `r-w`).
    pub first_payload_w: Option<Cycle>,
    /// Shared trace buffer, created and installed (controller + memory)
    /// when the controller's config enables tracing.  `Clone` detaches
    /// on purpose: the cross-check's shadow replay records into the
    /// void instead of double-logging (see `sim::trace`).
    tracer: Option<Tracer>,
}

impl<C: Controller> System<C> {
    pub fn new(profile: LatencyProfile, ctrl: C) -> Self {
        Self::with_memory(Memory::new(DEFAULT_MEM_BYTES, profile), ctrl)
    }

    pub fn with_memory(mut mem: Memory, mut ctrl: C) -> Self {
        let ports = ctrl.ports().to_vec();
        // The device under test owns the fault plan and the timing
        // backend (both are part of its configuration), but they run
        // inside the memory model: install them here, once, when the
        // two meet.
        mem.install_faults(ctrl.fault_config());
        mem.install_backend(ctrl.mem_backend());
        // The trace handle follows the same pattern, after the backend
        // (a backend swap builds a fresh DRAM core).  When tracing is
        // off, nothing is installed and every component carries `None`
        // — cycle-identical to the pre-trace model by construction.
        let tracer = if ctrl.trace_enabled() {
            let t = Tracer::new();
            ctrl.install_tracer(&t);
            mem.install_tracer(&t);
            Some(t)
        } else {
            None
        };
        Self {
            mem,
            ctrl,
            monitor: BusMonitor::new(),
            launches: VecDeque::new(),
            ar_arb: Arbiter::new(ports.clone()),
            w_arb: Arbiter::new(ports),
            extra_mems: Vec::new(),
            xbar: None,
            xbar_synced: false,
            now: 0,
            budget: CycleBudget::default(),
            horizon: EventHorizon::default(),
            irqs_seen: 0,
            irq_edges: Vec::new(),
            ring_irq_edges: Vec::new(),
            fault_edges: Vec::new(),
            error_irq_edges: Vec::new(),
            first_ar: Vec::new(),
            first_payload_r: None,
            first_payload_w: None,
            tracer,
        }
    }

    /// Build a system whose bus is an N×M crossbar over
    /// `cfg.controllers` address-interleaved memory controllers
    /// (`axi::crossbar`).  A single-controller crossbar is
    /// cycle-identical to [`System::new`]'s shared bus (property-tested
    /// in `tests/xbar.rs`).  The fault plan and timing backend are
    /// installed on every controller — at `M > 1` each memory draws
    /// from its own deterministic fault budget.  The trace buffer, when
    /// enabled, records controller 0 only.
    pub fn with_crossbar(profile: LatencyProfile, ctrl: C, cfg: XbarConfig) -> Self {
        let mut sys = Self::new(profile, ctrl);
        let mut extras = Vec::new();
        for _ in 1..cfg.controllers {
            let mut m = Memory::new(sys.mem.size(), profile);
            m.install_faults(sys.ctrl.fault_config());
            m.install_backend(sys.ctrl.mem_backend());
            extras.push(m);
        }
        sys.xbar = Some(Crossbar::new(
            sys.ctrl.ports().to_vec(),
            ArbPolicy::RoundRobin,
            Vec::new(),
            cfg,
        ));
        sys.extra_mems = extras;
        sys
    }

    /// The interconnect, when this is a crossbar system.
    pub fn xbar(&self) -> Option<&Crossbar> {
        self.xbar.as_ref()
    }

    /// Memory controllers beyond controller 0 (empty on a shared bus).
    pub fn extra_mems(&self) -> &[Memory] {
        &self.extra_mems
    }

    /// Number of memory controllers this system drives.
    pub fn controllers(&self) -> usize {
        1 + self.extra_mems.len()
    }

    /// The installed trace buffer (Some only when the controller's
    /// config enables tracing).
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Drain the collected trace (empty when tracing is off).
    pub fn take_trace(&mut self) -> Vec<TraceRecord> {
        self.tracer.as_ref().map(Tracer::take).unwrap_or_default()
    }

    fn trace(&self, ev: TraceEvent) {
        if let Some(t) = self.tracer.as_ref() {
            t.emit(self.now, ev);
        }
    }

    pub fn with_budget(mut self, budget: CycleBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Select the AR/W arbitration policy (paper default: fair RR).
    /// Port weights are taken from the controller
    /// ([`Controller::port_weights`], i.e. `DmacConfig::weight` per
    /// channel).
    pub fn with_arbitration(mut self, policy: ArbPolicy) -> Self {
        let ports = self.ctrl.ports().to_vec();
        let weights = self.ctrl.port_weights();
        self.ar_arb = Arbiter::with_policy(ports.clone(), policy, weights.clone());
        self.w_arb = Arbiter::with_policy(ports, policy, weights.clone());
        if let Some(x) = self.xbar.as_mut() {
            x.set_policy(policy, weights);
        }
        self
    }

    /// Grants issued so far on the AR and W arbiters for `port`
    /// (QoS/fairness diagnostics).  On a crossbar system, summed over
    /// every output port's arbiters.
    pub fn grants_to(&self, port: Port) -> (u64, u64) {
        if let Some(x) = self.xbar.as_ref() {
            return x.grants_to(port);
        }
        (self.ar_arb.grants_to(port), self.w_arb.grants_to(port))
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Schedule a CSR write (the launch unit's job) at cycle `at`,
    /// on channel 0.
    pub fn schedule_launch(&mut self, at: Cycle, desc_addr: u64) {
        self.schedule_launch_on(at, 0, desc_addr);
    }

    /// Schedule a banked CSR write on channel `ch` at cycle `at`.
    pub fn schedule_launch_on(&mut self, at: Cycle, ch: usize, desc_addr: u64) {
        debug_assert!(at >= self.now);
        self.launches.push_back((at, ch, LaunchOp::Csr(desc_addr)));
    }

    /// Schedule a submission-ring doorbell write on channel `ch`:
    /// publish ring entries up to free-running tail index `tail`.
    pub fn schedule_doorbell(&mut self, at: Cycle, ch: usize, tail: u64) {
        debug_assert!(at >= self.now);
        self.launches.push_back((at, ch, LaunchOp::Doorbell(tail)));
    }

    /// Schedule a completion-ring consumer-doorbell write on channel
    /// `ch`: software consumed records up to free-running index `head`.
    pub fn schedule_cq_doorbell(&mut self, at: Cycle, ch: usize, head: u64) {
        debug_assert!(at >= self.now);
        self.launches.push_back((at, ch, LaunchOp::CqDoorbell(head)));
    }

    /// Schedule a channel-reset CSR write on channel `ch` at cycle
    /// `at`: clears the sticky error CSR and drops the channel's queued
    /// work so a recovery driver can resubmit.
    pub fn schedule_reset(&mut self, at: Cycle, ch: usize) {
        debug_assert!(at >= self.now);
        self.launches.push_back((at, ch, LaunchOp::Reset));
    }

    /// Backdoor-load a chain and schedule its launch `at` cycle.
    pub fn load_and_launch(&mut self, at: Cycle, chain: &ChainBuilder) -> u64 {
        self.load_and_launch_on(at, 0, chain)
    }

    /// Backdoor-load a chain and schedule its launch on channel `ch`.
    /// On a crossbar system the chain is written into every
    /// controller's byte image, so mid-run loads (e.g. a recovery
    /// relaunch) stay consistent across the interleave.
    pub fn load_and_launch_on(&mut self, at: Cycle, ch: usize, chain: &ChainBuilder) -> u64 {
        let head = chain.write_to(&mut self.mem);
        for m in &mut self.extra_mems {
            chain.write_to(m);
        }
        self.schedule_launch_on(at, ch, head);
        head
    }

    /// Advance one clock cycle (see `dmac::controller` for the
    /// intra-cycle protocol).
    pub fn tick(&mut self) {
        let now = self.now;
        // Launch unit: MMIO writes scheduled for this cycle.  The
        // schedule need not be time-sorted (independent drivers push
        // interleaved launches and doorbells), so scan the whole queue;
        // eligible entries fire in queue order.
        let mut i = 0;
        while i < self.launches.len() {
            let (at, ch, op) = self.launches[i];
            if at > now {
                i += 1;
                continue;
            }
            let _ = self.launches.remove(i);
            match op {
                LaunchOp::Csr(addr) => {
                    self.trace(TraceEvent::CsrLaunch { addr });
                    self.ctrl.csr_write_ch(now, ch, addr);
                }
                LaunchOp::Doorbell(tail) => {
                    self.trace(TraceEvent::SqDoorbell { ch: ch as u8, tail });
                    self.ctrl.ring_doorbell(now, ch, tail);
                }
                LaunchOp::CqDoorbell(head) => {
                    self.trace(TraceEvent::CqDoorbell { ch: ch as u8, head });
                    self.ctrl.ring_cq_doorbell(now, ch, head);
                }
                LaunchOp::Reset => {
                    self.trace(TraceEvent::MmioReset { ch: ch as u8 });
                    self.ctrl.channel_reset(now, ch);
                }
            }
        }
        if self.xbar.is_some() {
            self.tick_bus_xbar(now);
        } else {
            self.tick_bus_shared(now);
        }
        {
            let irqs_seen = &mut self.irqs_seen;
            let per_ch = &mut self.irq_edges;
            self.ctrl.take_irq_channels(&mut |ch, n| {
                *irqs_seen += n;
                if per_ch.len() <= ch {
                    per_ch.resize(ch + 1, 0);
                }
                per_ch[ch] += n;
            });
        }
        {
            let irqs_seen = &mut self.irqs_seen;
            let per_ch = &mut self.ring_irq_edges;
            self.ctrl.take_ring_irq_channels(&mut |ch, n| {
                *irqs_seen += n;
                if per_ch.len() <= ch {
                    per_ch.resize(ch + 1, 0);
                }
                per_ch[ch] += n;
            });
        }
        {
            let per_ch = &mut self.fault_edges;
            self.ctrl.take_fault_channels(&mut |ch, n| {
                if per_ch.len() <= ch {
                    per_ch.resize(ch + 1, 0);
                }
                per_ch[ch] += n;
            });
        }
        {
            // Error IRQs, like IOMMU faults, count separately from the
            // completion IRQ total (`irqs_seen` stays a completion-path
            // metric; `RunStats::error_irqs` tracks the error edges).
            let per_ch = &mut self.error_irq_edges;
            self.ctrl.take_error_irq_channels(&mut |ch, n| {
                if per_ch.len() <= ch {
                    per_ch.resize(ch + 1, 0);
                }
                per_ch[ch] += n;
            });
        }
        self.monitor.tick();
        if let Some(x) = self.xbar.as_mut() {
            x.tick_monitors();
        }
        self.now += 1;
    }

    /// Legacy shared-bus data path: one memory, one AR grant and one W
    /// beat per cycle through the global arbiter pair.
    fn tick_bus_shared(&mut self, now: Cycle) {
        // Memory pipelines advance, then response channels deliver.
        self.mem.tick(now);
        if let Some(beat) = self.mem.pop_read_beat(now) {
            self.monitor.count_read_beat(beat.port, beat.bytes);
            if beat.port.is_payload() && self.first_payload_r.is_none() {
                self.first_payload_r = Some(now);
            }
            self.ctrl.on_r_beat(now, beat);
        }
        if let Some(b) = self.mem.pop_b(now) {
            self.ctrl.on_b(now, b);
        }
        // Internal state machines (same-cycle mispredict reissue
        // happens here, before AR arbitration).
        self.ctrl.step(now);
        // AR channel: one grant per cycle across the controller's
        // manager ports, under the configured arbitration policy (fair
        // RR by default — the paper's Fig. 3 testbench).  A port whose
        // `pop_ar` declines (e.g. engine start overhead) forfeits to
        // the next port without consuming arbitration state.
        {
            let ctrl = &mut self.ctrl;
            let mem = &mut self.mem;
            let first_ar = &mut self.first_ar;
            let _ = self.ar_arb.grant_with(|p| {
                if !ctrl.wants_ar(p) {
                    return None;
                }
                let req = ctrl.pop_ar(now, p)?;
                if first_ar.iter().all(|&(fp, _)| fp != p) {
                    first_ar.push((p, now));
                }
                mem.push_read(now, req);
                Some(())
            });
        }
        // W channel: one beat per cycle, same policy.
        {
            let ctrl = &mut self.ctrl;
            let mem = &mut self.mem;
            let monitor = &mut self.monitor;
            let first_payload_w = &mut self.first_payload_w;
            let _ = self.w_arb.grant_with(|p| {
                if !ctrl.wants_w(p) {
                    return None;
                }
                let w = ctrl.pop_w(now, p)?;
                monitor.count_write_beat(w.port, w.bytes);
                if w.port.is_payload() && first_payload_w.is_none() {
                    *first_payload_w = Some(now);
                }
                mem.push_write(now, w);
                Some(())
            });
        }
    }

    /// Crossbar data path: the same phase order as the shared bus, but
    /// every memory controller ticks, serves one R beat and one B, and
    /// grants one AR and one W through its own output arbiters.  A 1×1
    /// crossbar reproduces [`tick_bus_shared`](Self::tick_bus_shared)
    /// cycle for cycle (property-tested in `tests/xbar.rs`).
    fn tick_bus_xbar(&mut self, now: Cycle) {
        self.sync_images_once();
        let Self {
            ref mut mem,
            ref mut extra_mems,
            ref mut xbar,
            ref mut ctrl,
            ref mut monitor,
            ref mut first_ar,
            ref mut first_payload_r,
            ref mut first_payload_w,
            ..
        } = *self;
        let xbar = xbar.as_mut().expect("crossbar tick without a crossbar");
        mem.tick(now);
        for m in extra_mems.iter_mut() {
            m.tick(now);
        }
        // R: each controller serves at most one beat into its link;
        // each requester port consumes at most one merged beat.
        xbar.drain_r(now, mem, extra_mems);
        for pi in 0..xbar.ports().len() {
            if let Some(beat) = xbar.pop_r_for(pi) {
                monitor.count_read_beat(beat.port, beat.bytes);
                if beat.port.is_payload() && first_payload_r.is_none() {
                    *first_payload_r = Some(now);
                }
                ctrl.on_r_beat(now, beat);
            }
        }
        // B: one pop per controller; the crossbar folds scattered
        // writes' component responses back into original-burst Bs.
        for m in 0..=extra_mems.len() {
            let mm = if m == 0 { &mut *mem } else { &mut extra_mems[m - 1] };
            if let Some(b) = mm.pop_b(now) {
                if let Some(done) = xbar.route_b(b) {
                    ctrl.on_b(now, done);
                }
            }
        }
        ctrl.step(now);
        // AR / W: the crossbar offers each output port's grant through
        // the peek-route-pop contract (`Controller::ar_addr`/`w_addr`).
        xbar.grant_ar(now, mem, extra_mems, |p, routes_here| {
            if !ctrl.wants_ar(p) {
                return None;
            }
            let addr = ctrl.ar_addr(now, p)?;
            if !routes_here(addr) {
                return None;
            }
            let req = ctrl.pop_ar(now, p)?;
            if first_ar.iter().all(|&(fp, _)| fp != p) {
                first_ar.push((p, now));
            }
            Some(req)
        });
        xbar.grant_w(now, mem, extra_mems, |p, routes_here| {
            if !ctrl.wants_w(p) {
                return None;
            }
            let addr = ctrl.w_addr(now, p)?;
            if !routes_here(addr) {
                return None;
            }
            let w = ctrl.pop_w(now, p)?;
            monitor.count_write_beat(w.port, w.bytes);
            if w.port.is_payload() && first_payload_w.is_none() {
                *first_payload_w = Some(now);
            }
            Some(w)
        });
    }

    /// One-shot: copy controller 0's byte image into every extra
    /// controller on the first crossbar tick, so backdoor pre-loads
    /// (descriptor chains, source patterns) are visible through every
    /// interleave slice.  From then on the crossbar's write mirroring
    /// keeps the images coherent.
    fn sync_images_once(&mut self) {
        if self.xbar_synced {
            return;
        }
        self.xbar_synced = true;
        if self.extra_mems.is_empty() {
            return;
        }
        let img = self.mem.backdoor_read(0, self.mem.size()).to_vec();
        for m in &mut self.extra_mems {
            m.backdoor_write(0, &img);
        }
    }

    pub fn is_idle(&self) -> bool {
        self.launches.is_empty()
            && self.ctrl.idle()
            && self.mem.quiescent()
            && self.extra_mems.iter().all(Memory::quiescent)
            && self.xbar.as_ref().map_or(true, Crossbar::quiescent)
    }

    /// Earliest cycle at which any component acts without new input:
    /// the next scheduled CSR launch, the memory's pipeline deadlines,
    /// or the controller's internal state machines.  `None` means the
    /// whole system is input-free (idle or deadlocked).
    pub fn next_event(&self) -> Option<Cycle> {
        // The launch schedule is not necessarily time-sorted: take the
        // true minimum, not the front entry.
        let h = self.launches.iter().map(|&(at, _, _)| at).min();
        let h = EventHorizon::merge(h, self.mem.next_event());
        let mut h = EventHorizon::merge(h, self.ctrl.next_event());
        for m in &self.extra_mems {
            h = EventHorizon::merge(h, m.next_event());
        }
        if let Some(x) = self.xbar.as_ref() {
            h = EventHorizon::merge(h, x.next_event());
        }
        h
    }

    /// Fast-forward the clock to `to` without ticking: every cycle in
    /// `(now, to)` is dead by the `next_event` contract.  The bus
    /// monitor's cycle denominator advances so occupancy diagnostics
    /// stay identical to the naive loop.
    pub fn jump_to(&mut self, to: Cycle) {
        debug_assert!(to > self.now);
        #[cfg(debug_assertions)]
        {
            self.mem.debug_assert_quiet_before(to);
            for m in &self.extra_mems {
                m.debug_assert_quiet_before(to);
            }
        }
        self.horizon.record(self.now, to);
        self.monitor.advance(to - self.now);
        if let Some(x) = self.xbar.as_mut() {
            x.advance_monitors(to - self.now);
        }
        self.now = to;
    }

    /// One scheduler step: jump to the event horizon if it is strictly
    /// ahead, then execute one cycle.  The cycle budget is checked at
    /// jumps only (hot-path cost moved out of the per-cycle loop).
    pub fn advance(&mut self) -> crate::Result<()> {
        if let Some(h) = self.next_event() {
            if h > self.now {
                self.budget.check(h)?;
                self.jump_to(h);
            }
        }
        self.tick();
        Ok(())
    }

    /// Run until the whole system drains, returning the run's stats.
    ///
    /// Uses the event-horizon scheduler: cycle-identical to
    /// [`run_until_idle_naive`](Self::run_until_idle_naive) (property-
    /// tested), but dead latency windows are skipped in one jump.
    pub fn run_until_idle(&mut self) -> crate::Result<RunStats> {
        // A couple of settle cycles after apparent idleness flush
        // response pipes that are scheduled but not yet visible.
        let mut settle = 0;
        let mut steps: u64 = 0;
        while settle < 4 {
            if steps & BUDGET_CHECK_MASK == 0 {
                self.budget.check(self.now)?;
            }
            steps += 1;
            if self.is_idle() {
                settle += 1;
            } else {
                settle = 0;
            }
            self.advance()?;
        }
        // Outcome parity with the naive loop, which checks the budget
        // at every cycle up to end-1: a run that drains past the
        // budget without ever jumping near the limit must still error.
        if self.now > 0 {
            self.budget.check(self.now - 1)?;
        }
        Ok(self.finish_stats())
    }

    /// The original per-cycle loop, kept as the reference the fast
    /// scheduler is validated against (and as the `--naive` baseline
    /// for the §Perf throughput comparison).
    pub fn run_until_idle_naive(&mut self) -> crate::Result<RunStats> {
        let mut settle = 0;
        while settle < 4 {
            self.budget.check(self.now)?;
            if self.is_idle() {
                settle += 1;
            } else {
                settle = 0;
            }
            self.tick();
        }
        Ok(self.finish_stats())
    }

    /// Debug-mode cross-check: run a clone of this system through the
    /// naive per-cycle loop alongside the fast-forward loop and assert
    /// cycle-identical [`RunStats`].  Used by the equivalence property
    /// test; also handy when bringing up a new model's `next_event`.
    pub fn run_until_idle_cross_checked(&mut self) -> crate::Result<RunStats>
    where
        C: Clone,
    {
        let mut reference = self.clone();
        let fast = self.run_until_idle()?;
        let naive = reference.run_until_idle_naive()?;
        assert_eq!(
            fast, naive,
            "event-horizon fast-forward diverged from the naive tick loop \
             (skipped {} cycles in {} jumps)",
            self.horizon.skipped_cycles, self.horizon.jumps
        );
        Ok(fast)
    }

    fn finish_stats(&mut self) -> RunStats {
        let mut stats = self.ctrl.take_stats();
        stats.end_cycle = self.now;
        stats.irqs = self.irqs_seen;
        stats
    }

    /// `i-rf` (Table IV): cycles between the CSR write and the first
    /// descriptor read request of `port`.
    pub fn i_rf(&self, port: Port, csr_cycle: Cycle) -> Option<Cycle> {
        self.first_ar
            .iter()
            .find(|&&(p, _)| p == port)
            .map(|&(_, c)| c - csr_cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmac::{Descriptor, Dmac, DmacConfig};
    use crate::mem::backdoor::fill_pattern;

    fn simple_chain(n: usize, size: u32) -> ChainBuilder {
        let mut cb = ChainBuilder::new();
        for i in 0..n {
            let d = Descriptor::new(
                0x10_0000 + (i as u64) * 4096,
                0x20_0000 + (i as u64) * 4096,
                size,
            );
            let d = if i == n - 1 { d.with_irq() } else { d };
            cb.push_at(0x1000 + (i as u64) * 32, d);
        }
        cb
    }

    #[test]
    fn single_transfer_moves_the_bytes() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        fill_pattern(&mut sys.mem, 0x10_0000, 256, 42);
        let chain = simple_chain(1, 256);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(
            sys.mem.backdoor_read(0x10_0000, 256).to_vec(),
            sys.mem.backdoor_read(0x20_0000, 256).to_vec()
        );
        // Completion stamp over the descriptor's first 8 bytes.
        assert_eq!(sys.mem.backdoor_read_u64(0x1000), u64::MAX);
        assert_eq!(stats.irqs, 1);
    }

    #[test]
    fn chain_executes_in_order_and_stamps_all() {
        let mut sys =
            System::new(LatencyProfile::Ddr3, Dmac::new(DmacConfig::speculation()));
        for i in 0..8u64 {
            fill_pattern(&mut sys.mem, 0x10_0000 + i * 4096, 64, i as u32);
        }
        let chain = simple_chain(8, 64);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 8);
        for i in 0..8u64 {
            assert_eq!(
                sys.mem.backdoor_read(0x10_0000 + i * 4096, 64).to_vec(),
                sys.mem.backdoor_read(0x20_0000 + i * 4096, 64).to_vec(),
                "transfer {i}"
            );
            assert_eq!(sys.mem.backdoor_read_u64(0x1000 + i * 32), u64::MAX);
        }
        // Sequentially laid-out chain => all speculation hits.
        assert_eq!(stats.spec_misses, 0);
        assert!(stats.spec_hits > 0);
    }

    #[test]
    fn i_rf_latency_is_three_cycles() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::scaled()));
        let chain = simple_chain(1, 64);
        sys.load_and_launch(10, &chain);
        sys.run_until_idle().unwrap();
        assert_eq!(sys.i_rf(Port::Frontend, 10), Some(3));
    }

    #[test]
    fn ideal_memory_base_reaches_ideal_utilization() {
        // Fig. 4a: in ideal memory the base configuration achieves the
        // ideal steady-state utilization for bus-aligned sizes.
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        let chain = simple_chain(64, 64);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        let u = stats.steady_utilization();
        let ideal = 64.0 / (64.0 + 32.0);
        assert!((u - ideal).abs() < 0.03, "u = {u}, ideal = {ideal}");
    }

    #[test]
    fn cycle_budget_catches_runaway() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()))
            .with_budget(CycleBudget { max_cycles: 50 });
        // Launch far beyond the budget: run_until_idle must error, not hang.
        let chain = simple_chain(1, 64);
        let head = chain.write_to(&mut sys.mem);
        sys.schedule_launch(1000, head);
        assert!(sys.run_until_idle().is_err());
    }

    #[test]
    fn budget_also_caught_by_the_naive_loop() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()))
            .with_budget(CycleBudget { max_cycles: 50 });
        let chain = simple_chain(1, 64);
        let head = chain.write_to(&mut sys.mem);
        sys.schedule_launch(1000, head);
        assert!(sys.run_until_idle_naive().is_err());
    }

    #[test]
    fn budget_outcome_parity_between_loops() {
        // A run that drains *past* the budget (rather than jumping
        // over it) must error in both loops, even though the fast loop
        // only spot-checks the budget on its hot path.
        let build = || {
            let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()))
                .with_budget(CycleBudget { max_cycles: 40 });
            sys.load_and_launch(0, &simple_chain(4, 256));
            sys
        };
        assert!(build().run_until_idle().is_err());
        assert!(build().run_until_idle_naive().is_err());
        // And a run safely inside the budget succeeds in both.
        let ok = || {
            let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()))
                .with_budget(CycleBudget { max_cycles: 100_000 });
            sys.load_and_launch(0, &simple_chain(1, 64));
            sys
        };
        assert!(ok().run_until_idle().is_ok());
        assert!(ok().run_until_idle_naive().is_ok());
    }

    fn checked_system(profile: LatencyProfile, cfg: DmacConfig) -> System<Dmac> {
        let mut sys = System::new(profile, Dmac::new(cfg));
        for i in 0..8u64 {
            fill_pattern(&mut sys.mem, 0x10_0000 + i * 4096, 256, i as u32);
        }
        sys.load_and_launch(5, &simple_chain(8, 256));
        sys
    }

    #[test]
    fn fast_forward_matches_naive_across_profiles() {
        for profile in
            [LatencyProfile::Ideal, LatencyProfile::Ddr3, LatencyProfile::UltraDeep]
        {
            for cfg in DmacConfig::paper_configs() {
                let mut fast = checked_system(profile, cfg);
                let mut naive = checked_system(profile, cfg);
                let f = fast.run_until_idle().unwrap();
                let n = naive.run_until_idle_naive().unwrap();
                assert_eq!(f, n, "{profile:?} {}", cfg.name());
                assert_eq!(fast.now(), naive.now());
                assert_eq!(
                    fast.monitor.cycles, naive.monitor.cycles,
                    "occupancy denominator must include skipped cycles"
                );
            }
        }
    }

    #[test]
    fn deep_memory_actually_fast_forwards() {
        let mut sys = checked_system(LatencyProfile::UltraDeep, DmacConfig::base());
        sys.run_until_idle().unwrap();
        assert!(sys.horizon.jumps > 0, "no jumps taken");
        assert!(
            sys.horizon.skipped_cycles > 100,
            "a 100-cycle memory must yield long dead windows, skipped only {}",
            sys.horizon.skipped_cycles
        );
    }

    #[test]
    fn cross_checked_run_agrees_with_itself() {
        let mut sys = checked_system(LatencyProfile::Ddr3, DmacConfig::speculation());
        let stats = sys.run_until_idle_cross_checked().unwrap();
        assert_eq!(stats.completions.len(), 8);
    }

    #[test]
    fn descriptor_fault_halts_then_reset_and_relaunch_recover() {
        use crate::axi::ERR_SLVERR;
        use crate::mem::FaultConfig;
        // One guaranteed SLVERR on the very first read beat — the
        // descriptor fetch — then a clean bus for the retry.
        let cfg = DmacConfig::base()
            .with_faults(FaultConfig::seeded(1).with_read_slverr(1_000_000).with_max_faults(1));
        let mut sys = System::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        fill_pattern(&mut sys.mem, 0x10_0000, 256, 42);
        let chain = simple_chain(1, 256);
        let head = sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle_cross_checked().unwrap();
        let err = sys.ctrl.error_csr(0).expect("channel halted on the errored fetch");
        assert_eq!(err.code, ERR_SLVERR);
        assert_eq!(err.addr, head);
        assert_eq!(stats.fault_halts, 1);
        assert_eq!(stats.axi_slverrs, 1);
        assert_eq!(sys.error_irq_edges, vec![1], "banked error IRQ raised");
        assert_eq!(stats.completions.len(), 0, "nothing completed");
        // Recovery: reset the channel, relaunch the same chain.
        let now = sys.now();
        sys.schedule_reset(now + 1, 0);
        sys.schedule_launch(now + 2, head);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.channel_resets, 1);
        assert_eq!(stats.completions.len(), 1);
        assert!(sys.ctrl.error_csr(0).is_none(), "reset cleared the CSR");
        assert_eq!(sys.mem.backdoor_read_u64(head), u64::MAX);
        assert_eq!(
            sys.mem.backdoor_read(0x10_0000, 256).to_vec(),
            sys.mem.backdoor_read(0x20_0000, 256).to_vec()
        );
    }

    #[test]
    fn withheld_b_trips_the_watchdog_and_reset_recovers() {
        use crate::axi::ERR_TIMEOUT;
        use crate::dmac::descriptor::error_stamp;
        use crate::mem::FaultConfig;
        // The payload write's B response is withheld exactly once: the
        // channel wedges awaiting the acknowledgement until the
        // watchdog trips, aborts the transfer, and halts the channel.
        let cfg = DmacConfig::base()
            .with_faults(FaultConfig::seeded(2).with_withheld_b(1_000_000).with_max_faults(1))
            .with_watchdog(500);
        let mut sys = System::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        fill_pattern(&mut sys.mem, 0x10_0000, 64, 9);
        let chain = simple_chain(1, 64);
        let head = sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle_cross_checked().unwrap();
        assert_eq!(stats.watchdog_trips, 1);
        assert_eq!(stats.aborted_transfers, 1);
        let err = sys.ctrl.error_csr(0).expect("watchdog halted the channel");
        assert_eq!(err.code, ERR_TIMEOUT);
        // The poisoned completion stamped the descriptor with the
        // timeout code, not the all-ones success stamp.
        assert_eq!(sys.mem.backdoor_read_u64(head), error_stamp(ERR_TIMEOUT));
        // Recovery: the withheld-B budget is spent, so the retry's
        // acknowledgement arrives and the transfer completes.
        let now = sys.now();
        sys.schedule_reset(now + 1, 0);
        sys.schedule_launch(now + 2, head);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(sys.mem.backdoor_read_u64(head), u64::MAX);
        assert_eq!(
            sys.mem.backdoor_read(0x10_0000, 64).to_vec(),
            sys.mem.backdoor_read(0x20_0000, 64).to_vec()
        );
    }

    #[test]
    fn decerr_data_beat_poisons_the_transfer_without_halting() {
        use crate::axi::ERR_DECERR;
        use crate::dmac::descriptor::error_stamp;
        use crate::mem::FaultConfig;
        // The source buffer sits in a DECERR hole: the data beats
        // error, the transfer aborts and its completion is poisoned,
        // but the channel itself stays healthy (a data error is the
        // transfer's problem, not the channel's).
        let cfg = DmacConfig::base().with_faults(
            FaultConfig::seeded(3).with_decerr_window(0x10_0000, 0x10_1000),
        );
        let mut sys = System::new(LatencyProfile::Ddr3, Dmac::new(cfg));
        let chain = simple_chain(1, 64);
        let head = sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle_cross_checked().unwrap();
        assert_eq!(stats.aborted_transfers, 1);
        assert!(stats.axi_decerrs > 0);
        assert!(sys.ctrl.error_csr(0).is_none(), "data errors do not halt the channel");
        assert_eq!(sys.mem.backdoor_read_u64(head), error_stamp(ERR_DECERR));
        assert_eq!(sys.error_irq_edges, vec![1], "poisoned stamp raises the error IRQ");
    }

    #[test]
    fn crossbar_system_moves_bytes_across_controllers() {
        let mut sys = System::with_crossbar(
            LatencyProfile::Ddr3,
            Dmac::new(DmacConfig::base()),
            XbarConfig::new(4, 6),
        );
        fill_pattern(&mut sys.mem, 0x10_0000, 256, 42);
        sys.load_and_launch(0, &simple_chain(1, 256));
        // Cross-checked: the event-horizon loop must stay bit-identical
        // to the naive loop through the interleaved data path.
        let stats = sys.run_until_idle_cross_checked().unwrap();
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(sys.controllers(), 4);
        assert_eq!(
            sys.mem.backdoor_read(0x10_0000, 256).to_vec(),
            sys.mem.backdoor_read(0x20_0000, 256).to_vec()
        );
        assert_eq!(sys.mem.backdoor_read_u64(0x1000), u64::MAX);
        // A 256 B copy spans four 64 B granules: every controller saw
        // read traffic.
        let x = sys.xbar().unwrap();
        assert!((0..4).all(|m| x.ar_grants(m) > 0), "all controllers exercised");
        // The destination image is mirrored on every controller.
        for m in sys.extra_mems() {
            assert_eq!(
                m.backdoor_read(0x20_0000, 256).to_vec(),
                sys.mem.backdoor_read(0x20_0000, 256).to_vec()
            );
        }
    }

    #[test]
    fn one_by_one_crossbar_matches_shared_bus_exactly() {
        let shared = || {
            let mut sys =
                System::new(LatencyProfile::Ddr3, Dmac::new(DmacConfig::speculation()));
            fill_pattern(&mut sys.mem, 0x10_0000, 256, 7);
            sys.load_and_launch(3, &simple_chain(4, 256));
            sys
        };
        let xbar = || {
            let mut sys = System::with_crossbar(
                LatencyProfile::Ddr3,
                Dmac::new(DmacConfig::speculation()),
                XbarConfig::new(1, 6),
            );
            fill_pattern(&mut sys.mem, 0x10_0000, 256, 7);
            sys.load_and_launch(3, &simple_chain(4, 256));
            sys
        };
        let a = shared().run_until_idle().unwrap();
        let b = xbar().run_until_idle().unwrap();
        assert_eq!(a, b, "1×1 crossbar must be cycle-identical to the shared bus");
        let (mut sa, mut sb) = (shared(), xbar());
        sa.run_until_idle().unwrap();
        sb.run_until_idle().unwrap();
        assert_eq!(sa.now(), sb.now());
        assert_eq!(sa.first_payload_r, sb.first_payload_r);
        assert_eq!(sa.first_payload_w, sb.first_payload_w);
        assert_eq!(sa.first_ar, sb.first_ar);
    }

    #[test]
    fn idle_system_reports_no_events() {
        let sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        assert!(sys.next_event().is_none());
        let mut sys = sys;
        let chain = simple_chain(1, 64);
        let head = chain.write_to(&mut sys.mem);
        sys.schedule_launch(42, head);
        assert_eq!(sys.next_event(), Some(42), "scheduled launch is the only event");
    }
}
