//! The out-of-context testbench (paper Fig. 3).
//!
//! A latency-configurable memory system and a *launch unit* driving
//! random streams of descriptors: both DMAC manager interfaces share
//! the memory through a fair round-robin arbiter; descriptors are
//! pre-loaded through a backdoor, and transfers are launched via the
//! DMAC's CSR.  The testbench is generic over [`Controller`], so the
//! same harness evaluates our DMAC and the LogiCORE baseline.

use crate::axi::{BusMonitor, Port};
use crate::dmac::{ChainBuilder, Controller};
use crate::mem::{LatencyProfile, Memory};
use crate::sim::{Cycle, CycleBudget, RunStats};
use std::collections::VecDeque;

/// Default simulated DRAM size: 16 MiB is enough for every paper sweep.
pub const DEFAULT_MEM_BYTES: usize = 16 << 20;

pub struct System<C: Controller> {
    pub mem: Memory,
    pub ctrl: C,
    pub monitor: BusMonitor,
    launches: VecDeque<(Cycle, u64)>,
    ar_rr: usize,
    w_rr: usize,
    now: Cycle,
    budget: CycleBudget,
    /// IRQ edges observed (the PLIC in the SoC model; a counter here).
    pub irqs_seen: u64,
    /// First AR issue cycle per port (Table IV `i-rf` / `rf-rb`).
    pub first_ar: Vec<(Port, Cycle)>,
    /// First payload R-beat delivery cycle (Table IV `r-w`).
    pub first_payload_r: Option<Cycle>,
    /// First payload W-beat issue cycle (Table IV `r-w`).
    pub first_payload_w: Option<Cycle>,
}

impl<C: Controller> System<C> {
    pub fn new(profile: LatencyProfile, ctrl: C) -> Self {
        Self::with_memory(Memory::new(DEFAULT_MEM_BYTES, profile), ctrl)
    }

    pub fn with_memory(mem: Memory, ctrl: C) -> Self {
        Self {
            mem,
            ctrl,
            monitor: BusMonitor::new(),
            launches: VecDeque::new(),
            ar_rr: 0,
            w_rr: 0,
            now: 0,
            budget: CycleBudget::default(),
            irqs_seen: 0,
            first_ar: Vec::new(),
            first_payload_r: None,
            first_payload_w: None,
        }
    }

    pub fn with_budget(mut self, budget: CycleBudget) -> Self {
        self.budget = budget;
        self
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    /// Schedule a CSR write (the launch unit's job) at cycle `at`.
    pub fn schedule_launch(&mut self, at: Cycle, desc_addr: u64) {
        debug_assert!(at >= self.now);
        self.launches.push_back((at, desc_addr));
    }

    /// Backdoor-load a chain and schedule its launch `at` cycle.
    pub fn load_and_launch(&mut self, at: Cycle, chain: &ChainBuilder) -> u64 {
        let head = chain.write_to(&mut self.mem);
        self.schedule_launch(at, head);
        head
    }

    /// Advance one clock cycle (see `dmac::controller` for the
    /// intra-cycle protocol).
    pub fn tick(&mut self) {
        let now = self.now;
        // Launch unit: CSR writes scheduled for this cycle.
        while let Some(&(at, addr)) = self.launches.front() {
            if at > now {
                break;
            }
            self.launches.pop_front();
            self.ctrl.csr_write(now, addr);
        }
        // Memory pipelines advance, then response channels deliver.
        self.mem.tick(now);
        if let Some(beat) = self.mem.pop_read_beat(now) {
            self.monitor.count_read_beat(beat.port, beat.bytes);
            if matches!(beat.port, Port::Backend | Port::LcBackend)
                && self.first_payload_r.is_none()
            {
                self.first_payload_r = Some(now);
            }
            self.ctrl.on_r_beat(now, beat);
        }
        if let Some(b) = self.mem.pop_b(now) {
            self.ctrl.on_b(now, b);
        }
        // Internal state machines (same-cycle mispredict reissue
        // happens here, before AR arbitration).
        self.ctrl.step(now);
        // AR channel: one grant per cycle, fair RR over the
        // controller's manager ports.  A port whose `pop_ar` declines
        // (e.g. engine start overhead) forfeits to the next port.
        let ports = self.ctrl.ports();
        let n = ports.len();
        for i in 0..n {
            let idx = (self.ar_rr + i) % n;
            let p = ports[idx];
            if self.ctrl.wants_ar(p) {
                if let Some(req) = self.ctrl.pop_ar(now, p) {
                    if self.first_ar.iter().all(|&(fp, _)| fp != p) {
                        self.first_ar.push((p, now));
                    }
                    self.mem.push_read(now, req);
                    self.ar_rr = (idx + 1) % n;
                    break;
                }
            }
        }
        // W channel: one beat per cycle, fair RR.
        for i in 0..n {
            let idx = (self.w_rr + i) % n;
            let p = ports[idx];
            if self.ctrl.wants_w(p) {
                if let Some(w) = self.ctrl.pop_w(now, p) {
                    self.monitor.count_write_beat(w.port, w.bytes);
                    if matches!(w.port, Port::Backend | Port::LcBackend)
                        && self.first_payload_w.is_none()
                    {
                        self.first_payload_w = Some(now);
                    }
                    self.mem.push_write(now, w);
                    self.w_rr = (idx + 1) % n;
                    break;
                }
            }
        }
        self.irqs_seen += self.ctrl.take_irq();
        self.monitor.tick();
        self.now += 1;
    }

    pub fn is_idle(&self) -> bool {
        self.launches.is_empty() && self.ctrl.idle() && self.mem.quiescent()
    }

    /// Run until the whole system drains, returning the run's stats.
    pub fn run_until_idle(&mut self) -> crate::Result<RunStats> {
        // A couple of settle cycles after apparent idleness flush
        // response pipes that are scheduled but not yet visible.
        let mut settle = 0;
        while settle < 4 {
            self.budget.check(self.now)?;
            if self.is_idle() {
                settle += 1;
            } else {
                settle = 0;
            }
            self.tick();
        }
        let mut stats = self.ctrl.take_stats();
        stats.end_cycle = self.now;
        stats.irqs = self.irqs_seen;
        Ok(stats)
    }

    /// `i-rf` (Table IV): cycles between the CSR write and the first
    /// descriptor read request of `port`.
    pub fn i_rf(&self, port: Port, csr_cycle: Cycle) -> Option<Cycle> {
        self.first_ar
            .iter()
            .find(|&&(p, _)| p == port)
            .map(|&(_, c)| c - csr_cycle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dmac::{Descriptor, Dmac, DmacConfig};
    use crate::mem::backdoor::fill_pattern;

    fn simple_chain(n: usize, size: u32) -> ChainBuilder {
        let mut cb = ChainBuilder::new();
        for i in 0..n {
            let d = Descriptor::new(
                0x10_0000 + (i as u64) * 4096,
                0x20_0000 + (i as u64) * 4096,
                size,
            );
            let d = if i == n - 1 { d.with_irq() } else { d };
            cb.push_at(0x1000 + (i as u64) * 32, d);
        }
        cb
    }

    #[test]
    fn single_transfer_moves_the_bytes() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        fill_pattern(&mut sys.mem, 0x10_0000, 256, 42);
        let chain = simple_chain(1, 256);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 1);
        assert_eq!(
            sys.mem.backdoor_read(0x10_0000, 256).to_vec(),
            sys.mem.backdoor_read(0x20_0000, 256).to_vec()
        );
        // Completion stamp over the descriptor's first 8 bytes.
        assert_eq!(sys.mem.backdoor_read_u64(0x1000), u64::MAX);
        assert_eq!(stats.irqs, 1);
    }

    #[test]
    fn chain_executes_in_order_and_stamps_all() {
        let mut sys =
            System::new(LatencyProfile::Ddr3, Dmac::new(DmacConfig::speculation()));
        for i in 0..8u64 {
            fill_pattern(&mut sys.mem, 0x10_0000 + i * 4096, 64, i as u32);
        }
        let chain = simple_chain(8, 64);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        assert_eq!(stats.completions.len(), 8);
        for i in 0..8u64 {
            assert_eq!(
                sys.mem.backdoor_read(0x10_0000 + i * 4096, 64).to_vec(),
                sys.mem.backdoor_read(0x20_0000 + i * 4096, 64).to_vec(),
                "transfer {i}"
            );
            assert_eq!(sys.mem.backdoor_read_u64(0x1000 + i * 32), u64::MAX);
        }
        // Sequentially laid-out chain => all speculation hits.
        assert_eq!(stats.spec_misses, 0);
        assert!(stats.spec_hits > 0);
    }

    #[test]
    fn i_rf_latency_is_three_cycles() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::scaled()));
        let chain = simple_chain(1, 64);
        sys.load_and_launch(10, &chain);
        sys.run_until_idle().unwrap();
        assert_eq!(sys.i_rf(Port::Frontend, 10), Some(3));
    }

    #[test]
    fn ideal_memory_base_reaches_ideal_utilization() {
        // Fig. 4a: in ideal memory the base configuration achieves the
        // ideal steady-state utilization for bus-aligned sizes.
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()));
        let chain = simple_chain(64, 64);
        sys.load_and_launch(0, &chain);
        let stats = sys.run_until_idle().unwrap();
        let u = stats.steady_utilization();
        let ideal = 64.0 / (64.0 + 32.0);
        assert!((u - ideal).abs() < 0.03, "u = {u}, ideal = {ideal}");
    }

    #[test]
    fn cycle_budget_catches_runaway() {
        let mut sys = System::new(LatencyProfile::Ideal, Dmac::new(DmacConfig::base()))
            .with_budget(CycleBudget { max_cycles: 50 });
        // Launch far beyond the budget: run_until_idle must error, not hang.
        let chain = simple_chain(1, 64);
        let head = chain.write_to(&mut sys.mem);
        sys.schedule_launch(1000, head);
        assert!(sys.run_until_idle().is_err());
    }
}
