//! Regenerates paper Fig. 4a: steady-state bus utilization vs transfer
//! size in an **ideal (1-cycle) memory system**.
//!
//! Paper claims reproduced here: the `base` configuration achieves the
//! ideal steady-state utilization ū = n/(n+32) for any bus-aligned
//! transfer size, and improves on the LogiCORE IP DMA by ~2.5x at 64 B.

mod common;

use common::{check_ratio, BenchTimer};
use idmac::mem::LatencyProfile;
use idmac::model::ideal_utilization;
use idmac::report::experiments::{self as exp, paper};

fn main() {
    let t = BenchTimer::start("fig4a_ideal_memory");
    exp::table1().print();
    let series = exp::fig4(LatencyProfile::Ideal);
    series.print();

    let base64 = series.at("base", 64.0).unwrap();
    let lc64 = series.at("LogiCORE", 64.0).unwrap();
    check_ratio(
        "base/LogiCORE @64B (ideal memory)",
        base64 / lc64,
        paper::FIG4A_64B_RATIO,
        1.8,
        3.2,
    );
    // Base tracks the Eq. 1 ideal for every bus-aligned size.
    let mut max_gap: f64 = 0.0;
    for &n in exp::FIG_SIZES.iter() {
        let u = series.at("base", n as f64).unwrap();
        max_gap = max_gap.max((ideal_utilization(n as f64) - u).abs());
    }
    println!("max |base - ideal| over sweep: {max_gap:.4} (paper: base == ideal)");
    t.finish(0);
}
