//! Regenerates paper Fig. 4b: utilization vs transfer size with the
//! **Genesys-2 DDR3 latency (13 cycles)**.
//!
//! Paper claims reproduced here: ideal steady-state utilization from
//! 256 B without and from 64 B with prefetching; up to 1.7x (base) and
//! 3.9x (speculation) over the LogiCORE at 64 B.

mod common;

use common::{check_ratio, BenchTimer};
use idmac::mem::LatencyProfile;
use idmac::model::ideal_utilization;
use idmac::report::experiments::{self as exp, paper};

fn main() {
    let t = BenchTimer::start("fig4b_ddr3_memory");
    exp::table1().print();
    let series = exp::fig4(LatencyProfile::Ddr3);
    series.print();

    let lc64 = series.at("LogiCORE", 64.0).unwrap();
    check_ratio(
        "base/LogiCORE @64B (DDR3)",
        series.at("base", 64.0).unwrap() / lc64,
        paper::FIG4B_64B_RATIO_BASE,
        1.4,
        2.4,
    );
    check_ratio(
        "speculation/LogiCORE @64B (DDR3)",
        series.at("speculation", 64.0).unwrap() / lc64,
        paper::FIG4B_64B_RATIO_SPEC,
        3.0,
        5.2,
    );
    // Crossover sizes: where each config first reaches ideal.
    for name in ["base", "speculation"] {
        let cross = exp::FIG_SIZES
            .iter()
            .find(|&&n| {
                (series.at(name, n as f64).unwrap() - ideal_utilization(n as f64)).abs() < 0.01
            })
            .copied();
        println!("{name}: ideal from {cross:?} B (paper: base 256 B, speculation 64 B)");
    }
    t.finish(0);
}
