//! Regenerates paper Fig. 4c: utilization vs transfer size in an
//! **ultra-deep memory system (100 cycles)**.
//!
//! Paper claims reproduced here: the `scaled` configuration (24
//! descriptors in flight, 24 speculation slots) achieves near-ideal
//! steady-state utilization even at 100-cycle latency (paper: ideal
//! from 128 B; our simulator reaches it from 64 B), extending the lead
//! over the LogiCORE at 64 B transfers.
//!
//! Known divergence (EXPERIMENTS.md §Fig.4c): the paper reports 3.6x
//! at 64 B; our strictly-serialized LogiCORE model collapses harder at
//! L = 100 than the real IP, so the measured ratio is far larger.  The
//! shape — who wins and where the crossover falls — holds.

mod common;

use common::{check_ratio, BenchTimer};
use idmac::mem::LatencyProfile;
use idmac::model::ideal_utilization;
use idmac::report::experiments::{self as exp, paper};

fn main() {
    let t = BenchTimer::start("fig4c_ultradeep_memory");
    exp::table1().print();
    let series = exp::fig4(LatencyProfile::UltraDeep);
    series.print();

    let lc64 = series.at("LogiCORE", 64.0).unwrap();
    let scaled64 = series.at("scaled", 64.0).unwrap();
    check_ratio(
        "scaled/LogiCORE @64B (ultra-deep)",
        scaled64 / lc64,
        paper::FIG4C_64B_RATIO,
        paper::FIG4C_64B_RATIO,
        f64::INFINITY,
    );
    println!(
        "note: ratio >> paper's 3.6x because the baseline model chases strictly \
         serialized descriptors; see EXPERIMENTS.md §Fig.4c"
    );
    // Ablation: grant the baseline a contiguous-BD-ring fetch-ahead of
    // its 4 in-flight descriptors (analytic model) — the ratio falls
    // back into the paper's band, quantifying the divergence.
    let m = idmac::model::UtilizationModel::new(100.0, 4, 0, 1.0);
    let lc_ring = m.logicore_ring(64.0, 4.0);
    println!(
        "ablation: LogiCORE w/ ring fetch-ahead x4 (analytic) @64B: {:.3} -> ratio {:.1}x \
         (paper: 3.6x)",
        lc_ring,
        scaled64 / lc_ring
    );
    let cross = exp::FIG_SIZES
        .iter()
        .find(|&&n| {
            (series.at("scaled", n as f64).unwrap() - ideal_utilization(n as f64)).abs() < 0.01
        })
        .copied();
    println!("scaled: ideal from {cross:?} B (paper: 128 B)");
    t.finish(0);
}
