//! Shared bench harness (criterion is not vendored offline; each bench
//! is a `harness = false` binary that prints the regenerated table or
//! figure, the paper-vs-measured comparison, and wall-clock timing).

// benches/ is the sanctioned wall-clock zone (DESIGN.md §14, lint rule
// `no-wall-clock`); clippy's disallowed-types config covers bench
// targets too, so the exemption is spelled out here.
#![allow(clippy::disallowed_types, clippy::disallowed_methods)]

use std::time::Instant;

pub struct BenchTimer {
    name: &'static str,
    start: Instant,
}

impl BenchTimer {
    pub fn start(name: &'static str) -> Self {
        println!("=== bench: {name} ===");
        Self { name, start: Instant::now() }
    }

    pub fn finish(self, simulated_cycles: u64) {
        let dt = self.start.elapsed().as_secs_f64();
        if simulated_cycles > 0 {
            println!(
                "[{}] wall {:.2}s, {} simulated cycles, {:.1} Mcycles/s",
                self.name,
                dt,
                simulated_cycles,
                simulated_cycles as f64 / dt / 1e6
            );
        } else {
            println!("[{}] wall {:.2}s", self.name, dt);
        }
    }
}

/// Print a paper-vs-measured ratio line with a band verdict.
/// (Not every bench target uses it — `mod common` is compiled per
/// bench, so the unused copies must not trip `-D warnings`.)
#[allow(dead_code)]
pub fn check_ratio(label: &str, measured: f64, paper: f64, lo: f64, hi: f64) {
    let verdict = if measured >= lo && measured <= hi { "OK (shape holds)" } else { "DEVIATION (see EXPERIMENTS.md)" };
    println!("{label}: measured {measured:.2}x vs paper {paper:.2}x — {verdict}");
}
