//! Regenerates paper Table IV: in-system latencies between the CSR
//! write, the frontend's descriptor read, the backend's payload read,
//! and the read→write datapath, for the `scaled` configuration vs the
//! LogiCORE at 1 / 13 / 100-cycle memory latency.
//!
//! Paper headline reproduced here: 3 vs 10 cycles `i-rf` (3.33x) and
//! the 2.75x / 1.5x / 1.08x `rf-rb` improvements — overall the
//! abstract's "1.66x less latency launching transfers".

mod common;

use common::BenchTimer;
use idmac::dmac::DmacConfig;
use idmac::mem::LatencyProfile;
use idmac::report::experiments::{self as exp, paper};

fn main() {
    let t = BenchTimer::start("table4_latencies");
    exp::table4().print();

    let profiles = [LatencyProfile::Ideal, LatencyProfile::Ddr3, LatencyProfile::UltraDeep];
    let mut max_dev = 0u64;
    for (i, p) in profiles.into_iter().enumerate() {
        let ours = exp::probe_ours(DmacConfig::scaled(), p);
        let lc = exp::probe_logicore(p);
        max_dev = max_dev
            .max(ours.rf_rb.abs_diff(paper::TABLE4_RF_RB_OURS[i]))
            .max(lc.rf_rb.abs_diff(paper::TABLE4_RF_RB_LC[i]));
        println!(
            "rf-rb improvement @L={}: {:.2}x (paper: {:.2}x)",
            p.cycles(),
            lc.rf_rb as f64 / ours.rf_rb as f64,
            paper::TABLE4_RF_RB_LC[i] as f64 / paper::TABLE4_RF_RB_OURS[i] as f64,
        );
    }
    let ours = exp::probe_ours(DmacConfig::scaled(), LatencyProfile::Ideal);
    let lc = exp::probe_logicore(LatencyProfile::Ideal);
    println!(
        "i-rf improvement: {:.2}x (paper: 3.33x); r-w: {} vs {} (paper: 1 vs 1)",
        lc.i_rf as f64 / ours.i_rf as f64,
        ours.r_w,
        lc.r_w
    );
    // Abstract headline: launch latency = i-rf + rf-rb at DDR3.
    let o = exp::probe_ours(DmacConfig::scaled(), LatencyProfile::Ddr3);
    let l = exp::probe_logicore(LatencyProfile::Ddr3);
    println!(
        "launch latency (i-rf + rf-rb, DDR3): {} vs {} = {:.2}x less (paper: 1.66x)",
        o.i_rf + o.rf_rb,
        l.i_rf + l.rf_rb,
        (l.i_rf + l.rf_rb) as f64 / (o.i_rf + o.rf_rb) as f64
    );
    println!("max |measured - paper| over Table IV: {max_dev} cycles (documented: ±2)");
    t.finish(0);
}
