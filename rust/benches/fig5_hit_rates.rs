//! Regenerates paper Fig. 5: steady-state utilization under
//! speculation **misses** — prefetch hit rates 100/75/50/25/0 % — in
//! the DDR3 memory system with the `speculation` configuration.
//!
//! Paper claim reproduced here: across 75 %…0 % hit rates the
//! improvement over the LogiCORE at 64 B still ranges from ~1.65x to
//! ~3.1x, and a misprediction adds no latency (the 0 %-hit curve
//! tracks the `base` configuration from Fig. 4b).

mod common;

use common::{check_ratio, BenchTimer};
use idmac::dmac::DmacConfig;
use idmac::mem::LatencyProfile;
use idmac::report::experiments::{self as exp, paper};
use idmac::workload::Sweep;

fn main() {
    let t = BenchTimer::start("fig5_hit_rates");
    exp::table1().print();
    let series = exp::fig5();
    series.print();

    let lc64 = series.at("LogiCORE", 64.0).unwrap();
    let hi = series.at("hit=75%", 64.0).unwrap() / lc64;
    let lo = series.at("hit=0%", 64.0).unwrap() / lc64;
    check_ratio("hit=75% vs LogiCORE @64B", hi, paper::FIG5_64B_RATIO_HI, 2.2, 4.4);
    check_ratio("hit=0%  vs LogiCORE @64B", lo, paper::FIG5_64B_RATIO_LO, 1.2, 2.6);

    // No-latency-penalty property: 0% hit rate ≈ prefetching disabled
    // (the only cost is discarded-fetch contention, §II-C).
    let base64 =
        exp::run_ours(DmacConfig::base(), LatencyProfile::Ddr3, Sweep::new(exp::CHAIN_LEN, 64))
            .steady_utilization();
    let h0 = series.at("hit=0%", 64.0).unwrap();
    println!(
        "0%-hit vs prefetch-disabled @64B: {h0:.3} vs {base64:.3} \
         (equal or slightly lower due to wasted-fetch contention only)"
    );
    assert!(h0 <= base64 + 0.01, "misprediction must not add latency beyond contention");
    assert!(h0 >= base64 * 0.7, "0% hit rate should roughly track base");
    t.finish(0);
}
