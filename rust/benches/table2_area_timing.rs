//! Regenerates paper Table II: ASIC area (kGE) and achievable clock
//! frequency per configuration (GF12LP+ typical corner model), plus
//! the paper's linear area fit A = 20.30 + 5.28d + 1.94s and the
//! under-10%-of-CVA6 scalability check.

mod common;

use common::BenchTimer;
use idmac::model::AreaModel;
use idmac::report::experiments::{self as exp, paper};

fn main() {
    let t = BenchTimer::start("table2_area_timing");
    exp::table2().print();

    let mut max_area_err: f64 = 0.0;
    let mut max_clk_err: f64 = 0.0;
    for (cfg, (_, _, _, p_total, p_ghz)) in
        idmac::dmac::DmacConfig::paper_configs().into_iter().zip(paper::TABLE2)
    {
        let r = AreaModel::report(cfg.in_flight, cfg.prefetch);
        max_area_err = max_area_err.max((r.total_kge - p_total).abs() / p_total);
        max_clk_err = max_clk_err.max((r.clock_ghz - p_ghz).abs() / p_ghz);
    }
    println!("max area error vs paper: {:.1}% (fit residual)", max_area_err * 100.0);
    println!("max clock error vs paper: {:.1}%", max_clk_err * 100.0);
    println!(
        "speculation adds {:.1} kGE (paper: 8.3 kGE)",
        AreaModel::total_kge(4, 4) - AreaModel::total_kge(4, 0)
    );
    println!(
        "scaled config is {:.1}% of a CVA6 core (paper: <10%)",
        AreaModel::fraction_of_cva6(24, 24) * 100.0
    );
    // Area linearity sweep — the "easily scaled" claim.
    println!("\narea sweep A(d, s) [kGE]:");
    for d in [4usize, 8, 16, 24, 32] {
        let row: Vec<String> =
            [0usize, 4, 8, 16, 24].iter().map(|&s| format!("{:>6.1}", AreaModel::total_kge(d, s))).collect();
        println!("  d={d:>2}: {}", row.join(" "));
    }
    t.finish(0);
}
