//! Regenerates paper Table III: FPGA resources (Kintex-7 @200 MHz
//! model) for the three DMAC configurations and the LogiCORE IP DMA,
//! plus the headline resource-reduction claims.

mod common;

use common::BenchTimer;
use idmac::model::FpgaModel;
use idmac::report::experiments::{self as exp};

fn main() {
    let t = BenchTimer::start("table3_fpga_resources");
    exp::table3().print();

    let spec = FpgaModel::ours(4, 4);
    let base = FpgaModel::ours(4, 0);
    let scaled = FpgaModel::ours(24, 24);
    let (lut_red, ff_red) = FpgaModel::reduction_vs_logicore(spec);
    println!(
        "speculation vs LogiCORE: {:.1}% fewer LUTs, {:.1}% fewer FFs \
         (paper headline: 11% / 23%)",
        lut_red * 100.0,
        ff_red * 100.0
    );
    let (lut_b, ff_b) = FpgaModel::reduction_vs_logicore(base);
    println!(
        "base vs LogiCORE: {:.2}% fewer LUTs, {:.1}% fewer FFs (paper: 6.25% / 39.8%)",
        lut_b * 100.0,
        ff_b * 100.0
    );
    let (socl, socf) = FpgaModel::soc_fraction(base);
    println!(
        "base as fraction of the CVA6 SoC: {:.1}% LUTs, {:.1}% FFs (paper: 3.3% / 5.3%)",
        socl * 100.0,
        socf * 100.0
    );
    println!(
        "scaled vs base: {:.2}x LUTs, {:.2}x FFs (paper: 2.59x / 3.67x)",
        scaled.luts as f64 / base.luts as f64,
        scaled.ffs as f64 / base.ffs as f64
    );
    println!("block RAMs: ours = 0 in every configuration (paper headline)");
    t.finish(0);
}
