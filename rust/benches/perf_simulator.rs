//! Simulator performance bench (§Perf in EXPERIMENTS.md): simulated
//! Mcycles/s of the L3 hot loop across representative workloads, in
//! both execution modes — the naive per-cycle tick loop and the
//! event-horizon fast-forward scheduler — plus the Fig. 4c grid
//! before/after comparison.  Emits `BENCH_sim_throughput.json` so the
//! perf trajectory is tracked PR over PR.  Not a paper figure.

mod common;

use common::BenchTimer;
use idmac::dmac::DmacConfig;
use idmac::mem::LatencyProfile;
use idmac::report::experiments as exp;
use idmac::report::{ThroughputEntry, ThroughputReport};
use idmac::workload::Sweep;

struct Case {
    name: &'static str,
    cfg: DmacConfig,
    profile: LatencyProfile,
    sweep: Sweep,
}

/// Warm-up run, then 3 timed repetitions; report best.
fn bench_case(case: &Case, naive: bool, report: &mut ThroughputReport) -> (u64, f64) {
    let _ = exp::run_ours_timed(case.cfg, case.profile, case.sweep, naive);
    let mut best: Option<exp::TimedRun> = None;
    for _ in 0..3 {
        let r = exp::run_ours_timed(case.cfg, case.profile, case.sweep, naive);
        if best.as_ref().map_or(true, |b| r.wall_seconds < b.wall_seconds) {
            best = Some(r);
        }
    }
    let best = best.unwrap();
    let cycles = best.stats.end_cycle;
    let mode = if naive { "naive" } else { "fast_forward" };
    println!(
        "{:<40} {cycles:>9} cycles  {:>8.1} Mcycles/s  ({:.4}s, {} jumps, {} skipped) [{mode}]",
        case.name,
        cycles as f64 / best.wall_seconds.max(1e-9) / 1e6,
        best.wall_seconds,
        best.ff_jumps,
        best.ff_skipped_cycles,
    );
    report.push(ThroughputEntry {
        label: case.name.into(),
        profile: case.profile.name(),
        config: case.cfg.name().into(),
        mode,
        simulated_cycles: cycles,
        wall_seconds: best.wall_seconds,
        ff_jumps: best.ff_jumps,
        ff_skipped_cycles: best.ff_skipped_cycles,
    });
    (cycles, best.wall_seconds)
}

fn main() {
    let t = BenchTimer::start("perf_simulator");
    let cases = [
        Case {
            name: "base/ideal/64B x1000",
            cfg: DmacConfig::base(),
            profile: LatencyProfile::Ideal,
            sweep: Sweep::new(1000, 64),
        },
        Case {
            name: "spec/ddr3/64B x1000",
            cfg: DmacConfig::speculation(),
            profile: LatencyProfile::Ddr3,
            sweep: Sweep::new(1000, 64),
        },
        Case {
            name: "scaled/deep/64B x1000",
            cfg: DmacConfig::scaled(),
            profile: LatencyProfile::UltraDeep,
            sweep: Sweep::new(1000, 64),
        },
        Case {
            name: "base/deep/64B x1000",
            cfg: DmacConfig::base(),
            profile: LatencyProfile::UltraDeep,
            sweep: Sweep::new(1000, 64),
        },
        Case {
            name: "scaled/ddr3/4KiB x500",
            cfg: DmacConfig::scaled(),
            profile: LatencyProfile::Ddr3,
            sweep: Sweep::new(500, 4096),
        },
        Case {
            name: "base/ideal/8B x2000",
            cfg: DmacConfig::base(),
            profile: LatencyProfile::Ideal,
            sweep: Sweep::new(2000, 8),
        },
    ];

    let mut report = ThroughputReport::new();
    let mut total_cycles = 0u64;
    let mut total_fast = 0.0f64;
    for case in &cases {
        let (_, naive_wall) = bench_case(case, true, &mut report);
        let (cycles, fast_wall) = bench_case(case, false, &mut report);
        report.push_speedup(case.name, naive_wall, fast_wall);
        println!(
            "{:<40} fast-forward speedup {:.2}x",
            case.name,
            naive_wall / fast_wall.max(1e-9)
        );
        total_cycles += cycles;
        total_fast += fast_wall;
    }

    // The acceptance measurement: the full Fig. 4c (ultra-deep) grid,
    // serial, naive vs fast-forward (same emitter as the CLI's
    // `bench-throughput`, so the JSON schema stays in one place).
    let (g_naive, g_fast) =
        exp::push_grid_comparison(&mut report, "fig4c-grid", LatencyProfile::UltraDeep);
    println!(
        "fig4c grid (ultra-deep): naive {g_naive:.3}s vs fast-forward {g_fast:.3}s \
         = {:.2}x (target: >= 3x)",
        g_naive / g_fast.max(1e-9)
    );

    let out = idmac::report::throughput::BENCH_FILE;
    match report.write(out) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
    println!(
        "aggregate (fast-forward): {:.1} Mcycles/s over {} simulated cycles",
        total_cycles as f64 / total_fast.max(1e-9) / 1e6,
        total_cycles
    );
    t.finish(total_cycles);
}
