//! Simulator performance bench (§Perf in EXPERIMENTS.md): simulated
//! Mcycles/s of the L3 hot loop across representative workloads.  This
//! is the harness used for the optimization pass — not a paper figure.

mod common;

use common::BenchTimer;
use idmac::dmac::DmacConfig;
use idmac::mem::LatencyProfile;
use idmac::report::experiments as exp;
use idmac::workload::Sweep;
use std::time::Instant;

fn bench_case(name: &str, cfg: DmacConfig, profile: LatencyProfile, sweep: Sweep) -> (u64, f64) {
    // Warm-up run, then 3 timed repetitions; report best.
    let _ = exp::run_ours(cfg, profile, sweep);
    let mut best = f64::INFINITY;
    let mut cycles = 0;
    for _ in 0..3 {
        let t0 = Instant::now();
        let stats = exp::run_ours(cfg, profile, sweep);
        let dt = t0.elapsed().as_secs_f64();
        cycles = stats.end_cycle;
        best = best.min(dt);
    }
    println!(
        "{name:<40} {cycles:>9} cycles  {:>7.1} Mcycles/s  ({:.4}s)",
        cycles as f64 / best / 1e6,
        best
    );
    (cycles, best)
}

fn main() {
    let t = BenchTimer::start("perf_simulator");
    let mut total_cycles = 0u64;
    let mut total_time = 0.0f64;
    for (name, cfg, profile, sweep) in [
        ("base/ideal/64B x1000", DmacConfig::base(), LatencyProfile::Ideal, Sweep::new(1000, 64)),
        ("spec/ddr3/64B x1000", DmacConfig::speculation(), LatencyProfile::Ddr3, Sweep::new(1000, 64)),
        ("scaled/deep/64B x1000", DmacConfig::scaled(), LatencyProfile::UltraDeep, Sweep::new(1000, 64)),
        ("scaled/ddr3/4KiB x500", DmacConfig::scaled(), LatencyProfile::Ddr3, Sweep::new(500, 4096)),
        ("base/ideal/8B x2000", DmacConfig::base(), LatencyProfile::Ideal, Sweep::new(2000, 8)),
    ] {
        let (c, s) = bench_case(name, cfg, profile, sweep);
        total_cycles += c;
        total_time += s;
    }
    println!(
        "aggregate: {:.1} Mcycles/s over {} simulated cycles",
        total_cycles as f64 / total_time / 1e6,
        total_cycles
    );
    t.finish(total_cycles);
}
