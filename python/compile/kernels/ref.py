"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernels (and, transitively, the Rust
cycle simulator's payload path) are validated against.  ``copy_engine_ref``
uses a sequential ``lax.scan`` so that chained descriptors observe
earlier writes — the same in-order semantics as the DMAC.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def copy_engine_ref(mem: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Sequentially apply line copies ``mem[dst[i]] = mem[src[i]]``."""

    def step(carry, sd):
        s, d = sd
        line = lax.dynamic_slice(carry, (s, 0), (1, carry.shape[1]))
        carry = lax.dynamic_update_slice(carry, line, (d, 0))
        return carry, ()

    out, _ = lax.scan(step, mem, (src, dst))
    return out


def gather_rows_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Vectorized gather ``table[idx]``."""
    return jnp.take(table, idx, axis=0)
