"""L1 Pallas kernel: irregular row gather.

The paper motivates the DMAC with sparse/irregular ML transfers (Kumar et
al., scatter-gather for graph analytics; embedding lookups).  This kernel
is that payload: gather ``len(idx)`` rows of an embedding table into a
dense output.  One grid step per gathered row — the same one-descriptor-
per-step structure as the DMAC's chain walk, and the BlockSpec-free
whole-array refs model the HBM-resident table with a VMEM-sized row move
per step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(idx_ref, table_ref, o_ref):
    i = pl.program_id(0)
    r = idx_ref[i]
    row = pl.load(table_ref, (pl.dslice(r, 1), slice(None)))
    pl.store(o_ref, (pl.dslice(i, 1), slice(None)), row)


def gather_rows(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather rows ``table[idx]`` with one grid step per row.

    Args:
      table: ``(rows, cols)`` embedding table.
      idx: ``(n,)`` int32 row indices (must be in-range; not clamped).

    Returns:
      ``(n, cols)`` gathered rows.
    """
    if table.ndim != 2:
        raise ValueError(f"table must be 2-D, got {table.shape}")
    if idx.ndim != 1:
        raise ValueError(f"idx must be 1-D, got {idx.shape}")
    (n,) = idx.shape
    return pl.pallas_call(
        _gather_kernel,
        grid=(n,),
        out_shape=jax.ShapeDtypeStruct((n, table.shape[1]), table.dtype),
        interpret=True,
    )(idx, table)
