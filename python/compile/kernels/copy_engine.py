"""L1 Pallas kernel: chained line-copy engine.

This is the data-movement hot-spot of the paper expressed for the TPU
memory hierarchy (see DESIGN.md §Hardware-Adaptation): a DMAC descriptor
chain is a schedule of line-granular memory moves.  The memory image is a
``(num_lines, line_words)`` array; descriptor *i* copies the line at row
``src[i]`` to row ``dst[i]``.  The grid dimension is the descriptor index
— i.e. the chain walk — and Pallas' sequential grid execution (in
``interpret=True`` mode, which is mandatory on the CPU PJRT plugin) gives
exactly the DMAC's in-order chain semantics: a later descriptor observes
the writes of every earlier one.

A ``src[i] == dst[i]`` descriptor is the identity and is used as chain
padding (the AOT artifact has a fixed descriptor count).

The kernel deliberately avoids ``input_output_aliases``: step 0 seeds the
output with the full memory image, later steps read *and* write the
output ref.  This keeps the lowered HLO free of donation metadata that
older PJRT runtimes handle inconsistently, at the cost of one full-image
copy (amortized over the whole chain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_engine_kernel(src_ref, dst_ref, mem_ref, o_ref):
    """One grid step == one descriptor: copy line src[i] -> dst[i]."""
    i = pl.program_id(0)

    # Seed the output image once; all subsequent descriptors mutate o_ref
    # in place, which is how the DMAC mutates DRAM.
    @pl.when(i == 0)
    def _seed():
        o_ref[...] = mem_ref[...]

    s = src_ref[i]
    d = dst_ref[i]
    # Read the source line *from the output image* so that chained
    # descriptors observe earlier writes (in-order semantics).
    line = pl.load(o_ref, (pl.dslice(s, 1), slice(None)))
    pl.store(o_ref, (pl.dslice(d, 1), slice(None)), line)


def copy_engine(mem: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Execute a descriptor chain over a memory image.

    Args:
      mem: ``(num_lines, line_words)`` integer memory image.
      src: ``(num_descriptors,)`` int32 source line indices.
      dst: ``(num_descriptors,)`` int32 destination line indices.

    Returns:
      The memory image after executing every descriptor in order.
    """
    if mem.ndim != 2:
        raise ValueError(f"mem must be 2-D (lines x words), got {mem.shape}")
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"src/dst must be matching 1-D, got {src.shape} vs {dst.shape}")
    (num_desc,) = src.shape
    if num_desc == 0:
        return mem
    return pl.pallas_call(
        _copy_engine_kernel,
        grid=(num_desc,),
        out_shape=jax.ShapeDtypeStruct(mem.shape, mem.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls.
    )(src, dst, mem)
