"""L2 JAX compute graph for the iDMA DMAC reproduction.

Three entry points, each AOT-lowered once by ``aot.py`` and loaded from
Rust via PJRT (Python is never on the simulation path):

* ``exec_chain``     — execute a descriptor chain over a memory image
                       (calls the L1 Pallas ``copy_engine`` kernel).
                       This is the *payload oracle*: the Rust cycle
                       simulator's final memory image must match it.
* ``gather_payload`` — the sparse ML gather payload the paper motivates
                       irregular transfers with (L1 ``gather`` kernel).
* ``utilization``    — the closed-form steady-state bus-utilization
                       model (Eq. 1 ideal curve + our DMAC + the
                       LogiCORE baseline), the analytic cross-check
                       series plotted next to the cycle-simulated
                       curves in the Fig. 4/5 benches.

The analytic model mirrors ``rust/src/model/utilization.rs`` — the two
implementations are cross-checked in ``rust/tests/runtime_oracle.rs``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.copy_engine import copy_engine
from compile.kernels.gather import gather_rows

# Bus geometry: 64-bit data bus => 8-byte beats; our descriptor is 256
# bits (4 beats), the LogiCORE descriptor is 13x32-bit words fetched over
# a 32-bit port (13 bus slots).  See DESIGN.md §7 for the calibration.
BYTES_PER_BEAT = 8.0
DESC_BEATS_OURS = 4.0
DESC_BEATS_LOGICORE = 13.0
FRONTEND_OVERHEAD_OURS = 2.0  # parse + backend-enqueue stages
FRONTEND_OVERHEAD_LOGICORE = 7.0  # 32-bit port packing + engine start
LOGICORE_PROC = 8.0  # serialized per-descriptor processing
LOGICORE_ENGINE_OVERHEAD = 4.0  # per-transfer engine overhead (beats)


def exec_chain(mem: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Run a (fixed-length, identity-padded) descriptor chain over ``mem``."""
    return copy_engine(mem, src, dst)


def gather_payload(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather embedding rows — the paper's irregular ML payload."""
    return gather_rows(table, idx)


def _beats(n):
    return jnp.ceil(n / BYTES_PER_BEAT)


def ideal_utilization(sizes):
    """Eq. 1: the descriptor-fetch-limited ideal, u = n / (n + 32)."""
    sizes = jnp.asarray(sizes, jnp.float32)
    return sizes / (sizes + 32.0)


def rf_rb_ours(latency):
    """Our frontend's read-request -> backend-handoff latency (cycles)."""
    return 2.0 * latency + DESC_BEATS_OURS + FRONTEND_OVERHEAD_OURS


def rf_rb_logicore(latency):
    """LogiCORE descriptor read round-trip (cycles)."""
    return 2.0 * latency + DESC_BEATS_LOGICORE + FRONTEND_OVERHEAD_LOGICORE


def chase_ours(latency):
    """Chase interval of our frontend: the ``next`` field arrives in the
    second descriptor beat (``2L + 1`` after the AR) and the next fetch
    is issued the same cycle (paper §II-C)."""
    return 2.0 * latency + 1.0


def utilization_ours(sizes, latency, in_flight, prefetch, hit_rate):
    """Steady-state utilization of our DMAC.

    ``prefetch == 0`` models the ``base`` configuration (strictly
    serialized pointer chase); ``prefetch > 0`` pipelines up to
    ``min(prefetch, in_flight)`` descriptor fetches, paying a full
    round-trip drain plus the flushed fetch beats on a misprediction
    (probability ``1 - hit_rate``).
    """
    sizes = jnp.asarray(sizes, jnp.float32)
    payload = _beats(sizes)
    work = DESC_BEATS_OURS + payload
    serial = chase_ours(latency)
    depth = jnp.maximum(jnp.minimum(prefetch, in_flight), 1.0)
    pipelined = serial / depth + (1.0 - hit_rate) * serial
    issue = jnp.where(prefetch > 0.0, pipelined, serial)
    waste = jnp.where(prefetch > 0.0, (1.0 - hit_rate) * depth * DESC_BEATS_OURS, 0.0)
    period = jnp.maximum(work + waste, issue)
    return payload / period


def utilization_logicore(sizes, latency):
    """Steady-state utilization of the LogiCORE IP DMA baseline."""
    sizes = jnp.asarray(sizes, jnp.float32)
    payload = _beats(sizes)
    work = DESC_BEATS_LOGICORE + payload + LOGICORE_ENGINE_OVERHEAD
    serial = rf_rb_logicore(latency) + LOGICORE_PROC
    period = jnp.maximum(work, serial)
    return payload / period


def utilization(sizes, latency, in_flight, prefetch, hit_rate):
    """(ideal, ours, logicore) utilization series — the AOT entry point."""
    return (
        ideal_utilization(sizes),
        utilization_ours(sizes, latency, in_flight, prefetch, hit_rate),
        utilization_logicore(sizes, latency),
    )
