"""The repo-specific determinism rules (DESIGN.md §14 has the table).

Every rule is structural and conservative: it matches token patterns in
scrubbed source (never comments/strings), and where it must reason
about values (rule 5) it evaluates the same const expressions the
compiler sees.  Sanctioned exceptions are narrow path allowlists
(benches may read the wall clock; ``report::timer`` *is* the injected
wall-clock boundary; ``sim/trace.rs`` implements the tracer itself).
"""

from __future__ import annotations

import re

from .engine import Finding, Rule
from .rust_tokens import ScrubbedSource, Token, match_brace

_SECTION = "DESIGN.md §14"


def _adjacent(tokens: list[Token], i: int, *texts: str) -> bool:
    if i + len(texts) > len(tokens):
        return False
    return all(tokens[i + k].text == t for k, t in enumerate(texts))


def _next_brace(tokens: list[Token], i: int) -> int:
    """Index of the next ``{`` at or after ``i`` (or -1)."""
    for j in range(i, len(tokens)):
        if tokens[j].text == "{":
            return j
    return -1


class NoWallClock(Rule):
    """Rule 1 — wall-clock types only in benches/ and report::timer."""

    rule_id = "no-wall-clock"
    summary = "no std::time::{Instant,SystemTime} outside benches/ and report::timer"

    ALLOWED = ("rust/benches/",)
    ALLOWED_FILES = ("rust/src/report/timer.rs",)
    NAMES = ("Instant", "SystemTime")

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        if sf.path.startswith(self.ALLOWED) or sf.path in self.ALLOWED_FILES:
            return []
        out = []
        for t in sf.tokens:
            if t.kind == "ident" and t.text in self.NAMES:
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=sf.path,
                        line=t.line,
                        message=(
                            f"wall-clock type `{t.text}` outside benches/ — simulated "
                            "results must not depend on wall time; observe it only "
                            f"through report::timer::Clock ({_SECTION})"
                        ),
                    )
                )
        return out


class NoHashCollections(Rule):
    """Rule 2 — HashMap/HashSet iteration order is ambient nondeterminism."""

    rule_id = "no-hash-collections"
    summary = "no HashMap/HashSet anywhere; use BTreeMap/BTreeSet or dense vecs"

    NAMES = ("HashMap", "HashSet")

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        out = []
        for t in sf.tokens:
            if t.kind == "ident" and t.text in self.NAMES:
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=sf.path,
                        line=t.line,
                        message=(
                            f"`{t.text}` has randomized iteration order — any walk over "
                            "it can reorder RunStats/trace/bench output; use "
                            f"BTree{t.text[4:]} or a dense Vec ({_SECTION})"
                        ),
                    )
                )
        return out


class NoFloatInBenchJson(Rule):
    """Rule 3 — no f32/f64 on paths that land in BENCH_*.json values.

    Structural approximation: inside the report modules (and
    ``sim/stats.rs``), flag float types/literals lexically inside
    (a) any ``fn`` whose name contains ``json`` and (b) the field block
    of any struct named ``*Point|*Entry|*Outcome|*Record|*Row`` — the
    serialized grid carriers.  Diagnostic helper methods returning f64
    (``hit_rate()`` etc.) stay legal: they never reach the JSON.
    """

    rule_id = "no-float-in-bench-json"
    summary = "BENCH_*.json grids are integer-only; floats stay in diagnostics"

    SCOPE_PREFIX = "rust/src/report/"
    SCOPE_FILES = ("rust/src/sim/stats.rs",)
    STRUCT_SUFFIXES = ("Point", "Entry", "Outcome", "Record", "Row")

    def _spans(self, tokens: list[Token]):
        """Yield (context, start, end) index spans to police."""
        for i, t in enumerate(tokens):
            if t.kind != "ident":
                continue
            if t.text == "fn" and i + 1 < len(tokens) and "json" in tokens[i + 1].text:
                b = _next_brace(tokens, i + 2)
                if b != -1:
                    yield f"fn {tokens[i + 1].text}", b, match_brace(tokens, b)
            if t.text == "struct" and i + 1 < len(tokens):
                name = tokens[i + 1].text
                if name.endswith(self.STRUCT_SUFFIXES):
                    b = _next_brace(tokens, i + 2)
                    # Tuple/unit structs have no brace block; skip if the
                    # next `{` belongs to something far away (a `;` or `(`
                    # before it means this wasn't a field block).
                    if b != -1 and not any(
                        tok.text in (";", "(") for tok in tokens[i + 2 : b]
                    ):
                        yield f"struct {name}", b, match_brace(tokens, b)

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        if not sf.path.startswith(self.SCOPE_PREFIX) and sf.path not in self.SCOPE_FILES:
            return []
        out = []
        for context, start, end in self._spans(sf.tokens):
            for t in sf.tokens[start : end + 1]:
                is_float = t.kind == "float" or (t.kind == "ident" and t.text in ("f32", "f64"))
                if is_float:
                    out.append(
                        Finding(
                            rule=self.rule_id,
                            path=sf.path,
                            line=t.line,
                            message=(
                                f"float `{t.text}` in {context} — BENCH_*.json values "
                                "are integer cycle counts; keep floats in diagnostic "
                                f"helpers or suppress with a reason ({_SECTION})"
                            ),
                        )
                    )
        return out


class TickableNextEvent(Rule):
    """Rule 4 — every impl Tickable must override next_event."""

    rule_id = "tickable-next-event"
    summary = "impl Tickable must override next_event (fast-forward correctness)"

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        out = []
        toks = sf.tokens
        for i, t in enumerate(toks):
            if not (t.kind == "ident" and t.text == "Tickable" and _adjacent(toks, i + 1, "for")):
                continue
            ty = next((x.text for x in toks[i + 2 : i + 8] if x.kind == "ident"), "?")
            b = _next_brace(toks, i + 2)
            if b == -1:
                continue
            end = match_brace(toks, b)
            has = any(
                toks[j].text == "fn" and _adjacent(toks, j + 1, "next_event")
                for j in range(b, end)
            )
            if not has:
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=sf.path,
                        line=t.line,
                        message=(
                            f"impl Tickable for `{ty}` does not override next_event — "
                            "the default `None` silently removes the component from "
                            f"event-horizon fast-forward ({_SECTION})"
                        ),
                    )
                )
        return out


# ---------------------------------------------------------------------------
# Rule 5: a tiny const-expression evaluator over the IRQ map constants.

_CONST = re.compile(r"pub\s+const\s+(\w+)\s*:\s*\w+\s*=\s*([^;]+);")
_GUARD = re.compile(r"const\s+_\s*:\s*\(\)\s*=")


def _eval_const(expr: str, env: dict[str, int]) -> int:
    """Evaluate ``expr`` (idents, ints, + - *, parens, `as` casts, paths,
    and the `.next_power_of_two()` const method the derived
    ``Plic::MAX_SOURCES`` uses)."""
    raw = re.findall(r"[A-Za-z_]\w*|0x[0-9a-fA-F_]+|\d[\d_]*|::|[()+\-*]", expr)
    toks: list[str] = []
    i = 0
    while i < len(raw):
        tok = raw[i]
        if tok == "::":  # path separator: the previous segment was a prefix
            if toks:
                toks.pop()
            i += 1
            continue
        if tok == "as":  # drop the cast and its target type
            i += 2
            continue
        toks.append(tok)
        i += 1

    def primary(i: int) -> tuple[int, int]:
        t = toks[i]
        if t == "(":
            v, i = add(i + 1)
            if i >= len(toks) or toks[i] != ")":
                raise ValueError("unbalanced parens")
            return v, i + 1
        if re.match(r"^(0x[0-9a-fA-F_]+|\d)", t):
            return int(t.replace("_", ""), 0), i + 1
        if t in env:
            return env[t], i + 1
        raise KeyError(t)

    def atom(i: int) -> tuple[int, int]:
        v, i = primary(i)
        # Postfix const methods.  The tokenizer drops `.`, so
        # `(expr).next_power_of_two()` scans as `expr next_power_of_two ( )`.
        while toks[i : i + 3] == ["next_power_of_two", "(", ")"]:
            v = 1 if v <= 1 else 1 << (v - 1).bit_length()
            i += 3
        return v, i

    def mul(i: int) -> tuple[int, int]:
        v, i = atom(i)
        while i < len(toks) and toks[i] == "*":
            r, i = atom(i + 1)
            v *= r
        return v, i

    def add(i: int) -> tuple[int, int]:
        v, i = mul(i)
        while i < len(toks) and toks[i] in "+-":
            op = toks[i]
            r, i = mul(i + 1)
            v = v + r if op == "+" else v - r
        return v, i

    v, i = add(0)
    if i != len(toks):
        raise ValueError(f"trailing tokens in {expr!r}")
    return v


def _resolve_consts(sources: list[str]) -> dict[str, int]:
    """Fixed-point resolve every `pub const NAME: T = expr;` in sources."""
    pending: dict[str, str] = {}
    for code in sources:
        for name, expr in _CONST.findall(code):
            pending.setdefault(name, expr)
    env: dict[str, int] = {}
    for _ in range(len(pending) + 1):
        progressed = False
        for name, expr in list(pending.items()):
            if name in env:
                continue
            try:
                env[name] = _eval_const(expr, env)
                progressed = True
            except (KeyError, ValueError):
                continue
        if not progressed:
            break
    return env


class IrqMapDisjoint(Rule):
    """Rule 5 — IRQ source banks disjoint and within PLIC capacity.

    Cross-checks the ``soc::mod.rs`` source-map constants as a function
    of ``MAX_CHANNELS`` (from ``axi/types.rs``) against
    ``Plic::MAX_SOURCES`` (``soc/plic.rs``), and requires the
    compile-time ``const _: () = ...`` guard blocks to exist in both
    ``soc/mod.rs`` and ``axi/types.rs`` so the same invariants also
    fail at cargo time.  Silent when the anchor files are absent (small
    fixture trees).
    """

    rule_id = "irq-map-disjoint"
    summary = "PLIC/IRQ source banks pairwise disjoint and below Plic::MAX_SOURCES"

    SOC = "rust/src/soc/mod.rs"
    TYPES = "rust/src/axi/types.rs"
    PLIC = "rust/src/soc/plic.rs"
    BANKS = ("DMAC_IRQ_SOURCE", "IOMMU_FAULT_SOURCE", "RING_IRQ_SOURCE", "ERROR_IRQ_SOURCE")

    def check_repo(self, root: str, files: dict[str, ScrubbedSource]) -> list[Finding]:
        soc = files.get(self.SOC)
        types = files.get(self.TYPES)
        if soc is None or types is None:
            return []
        out: list[Finding] = []
        plic = files.get(self.PLIC)

        # Plic::MAX_SOURCES lives in an impl block, so _CONST's `pub
        # const` shape still matches it.
        env = _resolve_consts(
            [types.code, soc.code] + ([plic.code] if plic is not None else [])
        )

        def fail(line: int, msg: str) -> None:
            out.append(Finding(rule=self.rule_id, path=self.SOC, line=line, message=msg))

        if "MAX_CHANNELS" not in env:
            fail(1, "could not resolve MAX_CHANNELS from axi/types.rs — rule anchor moved; update analysis/rules.py")
            return out
        missing = [b for b in self.BANKS if b not in env]
        if missing:
            fail(1, f"could not resolve IRQ bank constants {missing} from soc/mod.rs — rule anchor moved; update analysis/rules.py")
            return out

        width = env["MAX_CHANNELS"]
        banks = sorted(((env[b], b) for b in self.BANKS))
        for (base_a, name_a), (base_b, name_b) in zip(banks, banks[1:]):
            if base_a + width > base_b:
                fail(
                    1,
                    f"IRQ banks overlap: {name_a} [{base_a}, {base_a + width}) and "
                    f"{name_b} [{base_b}, {base_b + width}) collide for "
                    f"MAX_CHANNELS={width}",
                )
        if banks[0][0] < 1:
            fail(1, f"IRQ bank {banks[0][1]}={banks[0][0]} uses PLIC source 0, which is reserved")
        if "MAX_SOURCES" not in env:
            fail(1, "could not resolve Plic::MAX_SOURCES from soc/plic.rs — add the capacity constant the IRQ map is checked against")
        else:
            top = banks[-1][0] + width
            if top > env["MAX_SOURCES"]:
                fail(
                    1,
                    f"IRQ map tops out at source {top - 1} but Plic::MAX_SOURCES is "
                    f"{env['MAX_SOURCES']} — growing MAX_CHANNELS (ROADMAP item 2) "
                    "requires growing the PLIC first",
                )
        for path, sf in ((self.SOC, soc), (self.TYPES, types)):
            if not _GUARD.search(sf.code):
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=path,
                        line=1,
                        message=(
                            "missing `const _: () = { assert!(..) }` guard block — the "
                            f"IRQ-map/port-packing invariants must also fail at compile time ({_SECTION})"
                        ),
                    )
                )
        return out


class StatsCountersDocumented(Rule):
    """Rule 6 — every pub RunStats counter in to_json and DESIGN.md."""

    rule_id = "stats-counters-documented"
    summary = "pub RunStats counters must be serialized in to_json and documented in DESIGN.md"

    STATS = "rust/src/sim/stats.rs"
    SCALARS = ("u32", "u64", "usize", "Cycle")

    def _fields(self, sf: ScrubbedSource) -> list[tuple[str, int]]:
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.text == "struct" and _adjacent(toks, i + 1, "RunStats"):
                b = _next_brace(toks, i + 2)
                if b == -1:
                    return []
                end = match_brace(toks, b)
                fields = []
                j = b + 1
                depth = 0
                while j < end:
                    tok = toks[j]
                    if tok.text in "({<[":
                        depth += 1
                    elif tok.text in ")}>]":
                        depth -= 1
                    elif (
                        depth == 0
                        and tok.text == "pub"
                        and j + 3 < end
                        and toks[j + 1].kind == "ident"
                        and toks[j + 2].text == ":"
                        and toks[j + 3].kind == "ident"
                        and toks[j + 3].text in self.SCALARS
                        and j + 4 < end
                        and toks[j + 4].text == ","
                    ):
                        fields.append((toks[j + 1].text, toks[j + 1].line))
                        j += 4
                    j += 1
                return fields
        return []

    def check_repo(self, root: str, files: dict[str, ScrubbedSource]) -> list[Finding]:
        import os

        sf = files.get(self.STATS)
        if sf is None:
            return []
        fields = self._fields(sf)
        if not fields:
            return [
                Finding(
                    rule=self.rule_id,
                    path=self.STATS,
                    line=1,
                    message="could not locate `struct RunStats` fields — rule anchor moved; update analysis/rules.py",
                )
            ]
        # idents referenced inside fn to_json
        json_idents: set[str] = set()
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.text == "fn" and _adjacent(toks, i + 1, "to_json"):
                b = _next_brace(toks, i + 2)
                if b != -1:
                    end = match_brace(toks, b)
                    json_idents = {x.text for x in toks[b : end + 1] if x.kind == "ident"}
                break
        design_path = os.path.join(root, "DESIGN.md")
        design = None
        if os.path.exists(design_path):
            with open(design_path, "r", encoding="utf-8") as f:
                design = f.read()
        out = []
        for name, line in fields:
            if name not in json_idents:
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=self.STATS,
                        line=line,
                        message=(
                            f"pub RunStats counter `{name}` is not serialized by to_json — "
                            f"every counter must reach --stats-json output ({_SECTION})"
                        ),
                    )
                )
            if design is not None and not re.search(rf"\b{re.escape(name)}\b", design):
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=self.STATS,
                        line=line,
                        message=(
                            f"pub RunStats counter `{name}` is not mentioned in DESIGN.md — "
                            f"add it to the counter glossary ({_SECTION})"
                        ),
                    )
                )
        return out


class NoAmbientRng(Rule):
    """Rule 7 — seeded SplitMix64 only; no ambient RNG."""

    rule_id = "no-ambient-rng"
    summary = "no thread_rng/rand::random/from_entropy; seeded SplitMix64 only"

    NAMES = ("thread_rng", "ThreadRng", "from_entropy")

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        out = []
        toks = sf.tokens
        for i, t in enumerate(toks):
            if t.kind != "ident":
                continue
            hit = t.text in self.NAMES
            if (
                not hit
                and t.text == "random"
                and i >= 3
                and toks[i - 1].text == ":"
                and toks[i - 2].text == ":"
                and toks[i - 3].text == "rand"
            ):
                hit = True
            if hit:
                out.append(
                    Finding(
                        rule=self.rule_id,
                        path=sf.path,
                        line=t.line,
                        message=(
                            f"ambient RNG `{t.text}` — all randomness must flow from a "
                            f"replayable SplitMix64 seed (testutil::forall) ({_SECTION})"
                        ),
                    )
                )
        return out


class TraceObserverOnly(Rule):
    """Rule 8 — trace emission only through the `if let Some(t)` handle.

    Structural approximation of "tracer calls are observer-only": every
    ``.emit(..)`` receiver must be a binding introduced by
    ``if let Some(name) = <expr mentioning tracer>`` that is still in
    scope.  ``sim/trace.rs`` (the tracer's own impl and tests) is
    exempt.
    """

    rule_id = "trace-observer-only"
    summary = "Tracer::emit only via the `if let Some(t) = <tracer handle>` pattern"

    EXEMPT = ("rust/src/sim/trace.rs",)

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        if sf.path in self.EXEMPT:
            return []
        out = []
        toks = sf.tokens
        depth = 0
        active: list[tuple[int, str]] = []  # (brace depth of the binding's block, name)
        pending: list[str] = []
        i = 0
        while i < len(toks):
            t = toks[i]
            if t.text == "{":
                depth += 1
                for name in pending:
                    active.append((depth, name))
                pending = []
            elif t.text == "}":
                active = [(d, n) for (d, n) in active if d <= depth - 1]
                depth -= 1
            elif (
                t.text == "if"
                and _adjacent(toks, i + 1, "let", "Some", "(")
                and i + 4 < len(toks)
                and toks[i + 4].kind == "ident"
                and _adjacent(toks, i + 5, ")")
            ):
                name = toks[i + 4].text
                j = i + 6
                rhs_idents = []
                while j < len(toks) and toks[j].text != "{":
                    if toks[j].kind == "ident":
                        rhs_idents.append(toks[j].text)
                    j += 1
                if any("tracer" in x.lower() for x in rhs_idents):
                    pending.append(name)
                i = j
                continue
            elif t.text == "." and _adjacent(toks, i + 1, "emit", "("):
                recv = toks[i - 1].text if i > 0 else ""
                if not any(n == recv for (_d, n) in active):
                    out.append(
                        Finding(
                            rule=self.rule_id,
                            path=sf.path,
                            line=t.line,
                            message=(
                                f"`.emit(..)` on `{recv}` outside the `if let Some(t) = "
                                "<tracer handle>` observer pattern — trace emission must "
                                f"stay observer-only ({_SECTION})"
                            ),
                        )
                    )
            i += 1
        return out


#: Registration order == rule number in the DESIGN.md §14 table.
ALL_RULES: list[Rule] = [
    NoWallClock(),
    NoHashCollections(),
    NoFloatInBenchJson(),
    TickableNextEvent(),
    IrqMapDisjoint(),
    StatsCountersDocumented(),
    NoAmbientRng(),
    TraceObserverOnly(),
]
