"""Lightweight Rust scrubber and tokenizer for the lint engine.

The rules must never match inside comments or string literals (a doc
comment mentioning ``Instant`` is not a wall-clock call), so instead of
regexing raw text we run a small character-level state machine that:

* strips line comments and *nested* block comments,
* strips the interiors of string / byte-string / raw-string / char
  literals (quotes are kept so the token stream stays aligned),
* distinguishes char literals from lifetimes (``'a'`` vs ``&'a mut``),
* replaces everything stripped with spaces, preserving newlines, so
  byte offsets and line numbers in the scrubbed text match the source,
* collects the comments separately (with their line numbers) so the
  suppression syntax ``// lint:allow(rule-id, reason)`` can be parsed
  from them.

Attributes (``#[cfg(test)]``, ``#[derive(..)]``) are *kept* in the
token stream — rules may want them — but any string literals inside
them are scrubbed like everywhere else, so ``#[doc = "// x"]`` does not
fake a comment.

The tokenizer is deliberately coarse: identifiers, numeric literals
(with a dedicated ``float`` kind for ``1.5`` / ``2.0e3`` forms), and
single-character punctuation.  That is enough for every rule in
``rules.py``; none of them need full Rust parsing.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

# Opening of a raw (byte) string: r"..."  r#"..."#  br##"..."##
_RAW_OPEN = re.compile(r'(?:b?r|rb)(#*)"')

# One token of scrubbed code.  Floats before plain numbers so `1.5`
# lexes as one float token, not `1` `.` `5`; `1.` alone (rare in Rust,
# and absent from this repo) lexes as num + punct, which is fine.
_TOKEN = re.compile(
    r"(?P<ident>[A-Za-z_]\w*)"
    r"|(?P<float>\d[\d_]*\.\d[\d_]*(?:[eE][+-]?\d+)?|\d[\d_]*(?:[eE][+-]?\d+)|\d[\d_]*(?:f32|f64))"
    r"|(?P<num>\d[\w]*)"
    r"|(?P<punct>\S)"
)

# A char literal starting at a `'`: escape, unicode escape, or any
# single non-quote char, then the closing quote.  Anything else after
# `'` is a lifetime.
_CHAR_LIT = re.compile(r"'(?:\\(?:u\{[0-9a-fA-F_]+\}|.)|[^'\\\n])'")


@dataclass
class Comment:
    """One comment, with enough context to anchor suppressions."""

    line: int  # 1-based line of the comment's first character
    text: str  # full text including // or /* */ delimiters
    own_line: bool  # no code precedes it on its starting line


@dataclass
class Token:
    kind: str  # "ident" | "float" | "num" | "punct"
    text: str
    line: int  # 1-based


@dataclass
class ScrubbedSource:
    """A Rust file after comment/string scrubbing."""

    path: str
    raw: str
    code: str  # same shape as raw; stripped spans blanked with spaces
    comments: list[Comment] = field(default_factory=list)
    tokens: list[Token] = field(default_factory=list)

    def code_lines(self) -> list[str]:
        return self.code.split("\n")


def scrub(path: str, src: str) -> ScrubbedSource:
    """Strip comments and literal interiors from ``src``.

    Returns a :class:`ScrubbedSource` whose ``code`` is positionally
    identical to ``src`` (every stripped character becomes a space;
    newlines survive) and whose ``tokens`` are lexed from ``code``.
    """
    out: list[str] = []
    comments: list[Comment] = []
    i, n = 0, len(src)
    line = 1

    def blank(text: str) -> None:
        # Keep newlines so line numbers stay true.
        out.append("".join("\n" if ch == "\n" else " " for ch in text))

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""

        # Line comment (also covers /// and //!).
        if c == "/" and nxt == "/":
            start, start_line = i, line
            while i < n and src[i] != "\n":
                i += 1
            comments.append(Comment(start_line, src[start:i], own_line=False))
            blank(src[start:i])
            continue

        # Block comment — Rust block comments nest.
        if c == "/" and nxt == "*":
            start, start_line = i, line
            depth = 0
            while i < n:
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                    if depth == 0:
                        break
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            text = src[start:i]
            comments.append(Comment(start_line, text, own_line=False))
            blank(text)
            continue

        # Raw / byte-raw string.  The prefix must not be the tail of a
        # longer identifier (`for r in ...` followed by `"x"` cannot
        # happen token-wise, but `br` as a variable could precede a
        # string only via whitespace, which breaks the regex anyway).
        if c in "rb":
            prev = src[i - 1] if i > 0 else ""
            if not (prev.isalnum() or prev == "_"):
                m = _RAW_OPEN.match(src, i)
                if m:
                    hashes = m.group(1)
                    close = src.find('"' + hashes, m.end())
                    if close == -1:
                        close = n - len(hashes) - 1  # unterminated: eat rest
                    end = close + 1 + len(hashes)
                    text = src[i:end]
                    out.append(src[i : m.end()])
                    interior = src[m.end() : close]
                    blank(interior)
                    out.append(src[close:end])
                    line += text.count("\n")
                    i = end
                    continue

        # Plain string / byte string interior.
        if c == '"' or (c == "b" and nxt == '"' and not (i > 0 and (src[i - 1].isalnum() or src[i - 1] == "_"))):
            if c == "b":
                out.append("b")
                i += 1
            out.append('"')
            i += 1
            start = i
            while i < n:
                if src[i] == "\\" and i + 1 < n:
                    # `\<newline>` line continuations still end a line.
                    if src[i + 1] == "\n":
                        line += 1
                    i += 2
                    continue
                if src[i] == '"':
                    break
                if src[i] == "\n":
                    line += 1
                i += 1
            blank(src[start:i])
            if i < n:
                out.append('"')
                i += 1
            continue

        # Char literal vs lifetime.
        if c == "'":
            m = _CHAR_LIT.match(src, i)
            if m:
                out.append("'")
                blank(m.group(0)[1:-1])
                out.append("'")
                i = m.end()
                continue
            # Lifetime: keep the quote; the following ident lexes on its own.
            out.append("'")
            i += 1
            continue

        if c == "\n":
            line += 1
        out.append(c)
        i += 1

    code = "".join(out)
    sf = ScrubbedSource(path=path, raw=src, code=code, comments=comments)

    # own_line: the scrubbed code before the comment on its start line
    # is blank (comments themselves were blanked, so a trailing comment
    # leaves the statement text in place).
    lines = sf.code_lines()
    for cm in sf.comments:
        if cm.line - 1 < len(lines):
            cm.own_line = lines[cm.line - 1].strip() == ""

    # Tokenize per line so every token carries its line number.
    for lineno, text in enumerate(lines, start=1):
        for m in _TOKEN.finditer(text):
            kind = m.lastgroup or "punct"
            sf.tokens.append(Token(kind=kind, text=m.group(0), line=lineno))
    return sf


def match_brace(tokens: list[Token], open_index: int) -> int:
    """Index of the ``}`` matching ``tokens[open_index]`` (a ``{``).

    Returns ``len(tokens) - 1`` if unbalanced (never raises: rules must
    degrade gracefully on weird fixtures).
    """
    assert tokens[open_index].text == "{"
    depth = 0
    for j in range(open_index, len(tokens)):
        t = tokens[j].text
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
            if depth == 0:
                return j
    return len(tokens) - 1
