"""Determinism lint engine over the repo's Rust sources.

The simulator's headline claims rest on cycle determinism: naive-loop
vs event-horizon bit-identity, integer-only ``BENCH_*.json`` grids,
seeded fault plans.  This package enforces the structural side of that
contract statically, because the CI container that hosts most
verification is Python-only (no cargo).

Modules
-------
``rust_tokens``
    A lightweight Rust scrubber/tokenizer: comments, string literals
    and char literals are blanked (never regexed raw), line numbers are
    preserved, and comments are collected separately so inline
    ``// lint:allow(rule-id, reason)`` suppressions can be parsed.
``rules``
    The repo-specific rule set (see DESIGN.md §14 for the table).
``engine``
    Finding model, suppression application, baseline matching, file
    discovery and the ``run_analysis`` entrypoint used by
    ``python/ci/lint_rust.py``.
"""

from .engine import (  # noqa: F401
    AnalysisResult,
    BaselineEntry,
    Finding,
    apply_baseline,
    load_baseline,
    run_analysis,
)
from .rules import ALL_RULES  # noqa: F401
