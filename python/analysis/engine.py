"""Rule runner: findings, suppressions, baseline, file discovery.

Flow (mirrored by ``python/ci/lint_rust.py``):

1. discover ``*.rs`` under the scan roots (``rust/src``, ``rust/tests``,
   ``rust/benches``, ``examples``),
2. scrub + tokenize each file (:mod:`analysis.rust_tokens`),
3. run every registered rule; file rules see one file, repo rules see
   the whole tree (rule 5 cross-checks constants across three files,
   rule 6 reads DESIGN.md),
4. drop findings covered by an inline
   ``// lint:allow(rule-id, reason)`` — a missing reason voids the
   suppression and is itself a finding,
5. split the remainder against the checked-in baseline
   (``python/analysis/baseline.json``): matched findings are
   *baselined* (grandfathered), unmatched are *active* (CI-fatal), and
   baseline entries matching nothing are *stale* (also CI-fatal, so
   the baseline can only shrink).

Baseline entries match on ``(rule, path, message)`` — deliberately not
on line numbers, so unrelated edits to a grandfathered file do not
churn the baseline.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field

from .rust_tokens import ScrubbedSource, scrub

#: Directories (relative to the repo root) scanned for Rust sources.
SCAN_ROOTS = ("rust/src", "rust/tests", "rust/benches", "examples")

BASELINE_SCHEMA = "idmac-lint-baseline/v1"
REPORT_SCHEMA = "idmac-lint/v1"

# lint:allow(rule-id, reason) inside a comment.  The reason runs to the
# closing paren and must be non-empty after stripping.
_ALLOW = re.compile(r"lint:allow\(\s*([A-Za-z0-9_-]+)\s*(?:,\s*([^)]*))?\)")

# How far below an own-line suppression comment the suppressed code may
# sit (doc comments and attributes between are skipped because they
# scrub to blank / are crossed over line by line).
_OWN_LINE_REACH = 3


@dataclass
class Finding:
    rule: str
    path: str  # repo-relative, forward slashes
    line: int  # 1-based
    message: str

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class BaselineEntry:
    rule: str
    path: str
    message: str
    why: str = ""

    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "message": self.message}
        if self.why:
            d["why"] = self.why
        return d


@dataclass
class AnalysisResult:
    findings: list[Finding]  # post-suppression, pre-baseline
    suppressed: list[Finding]
    files_scanned: int
    rules_run: int


class Rule:
    """Base class; subclasses set ``rule_id`` and override one hook.

    ``check_file`` runs once per scanned file; ``check_repo`` runs once
    with every scrubbed file plus the repo root (for non-Rust inputs
    like DESIGN.md).  A rule may implement either or both.
    """

    rule_id: str = ""
    summary: str = ""

    def check_file(self, sf: ScrubbedSource) -> list[Finding]:
        return []

    def check_repo(self, root: str, files: dict[str, ScrubbedSource]) -> list[Finding]:
        return []


def discover_files(root: str) -> list[str]:
    """Repo-relative paths of every ``*.rs`` under the scan roots."""
    found = []
    for sub in SCAN_ROOTS:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirnames, filenames in os.walk(base):
            for name in sorted(filenames):
                if name.endswith(".rs"):
                    full = os.path.join(dirpath, name)
                    rel = os.path.relpath(full, root).replace(os.sep, "/")
                    found.append(rel)
    return sorted(found)


def _suppressions(sf: ScrubbedSource) -> tuple[dict[int, set[str]], list[Finding]]:
    """Map ``line -> {rule ids allowed on that line}`` plus defects.

    A trailing comment covers its own line; an own-line comment covers
    the next line that carries code (within ``_OWN_LINE_REACH`` lines,
    skipping blank/comment-only lines).  ``lint:allow`` without a
    reason emits a ``suppression-needs-reason`` finding and suppresses
    nothing.
    """
    allowed: dict[int, set[str]] = {}
    defects: list[Finding] = []
    lines = sf.code_lines()
    for cm in sf.comments:
        for m in _ALLOW.finditer(cm.text):
            rule_id = m.group(1)
            reason = (m.group(2) or "").strip()
            if not reason:
                defects.append(
                    Finding(
                        rule="suppression-needs-reason",
                        path=sf.path,
                        line=cm.line,
                        message=(
                            f"lint:allow({rule_id}) carries no reason — suppressions "
                            "must say why (DESIGN.md §14); this one is ignored"
                        ),
                    )
                )
                continue
            target = cm.line
            if cm.own_line:
                # Walk down to the next line with code.
                for cand in range(cm.line + 1, min(cm.line + 1 + _OWN_LINE_REACH, len(lines) + 1)):
                    if cand - 1 < len(lines) and lines[cand - 1].strip():
                        target = cand
                        break
            allowed.setdefault(target, set()).add(rule_id)
    return allowed, defects


def run_analysis(root: str, rules=None, files=None) -> AnalysisResult:
    """Run ``rules`` over the tree at ``root``.

    ``files`` (repo-relative paths) narrows the scan; repo rules always
    see every discovered file so cross-file checks stay sound.
    """
    from .rules import ALL_RULES

    active_rules = list(rules) if rules is not None else list(ALL_RULES)
    all_paths = discover_files(root)
    scan_paths = [p for p in all_paths if files is None or p in set(files)]

    scrubbed: dict[str, ScrubbedSource] = {}
    for rel in all_paths:
        with open(os.path.join(root, rel), "r", encoding="utf-8") as f:
            scrubbed[rel] = scrub(rel, f.read())

    raw: list[Finding] = []
    for rule in active_rules:
        for rel in scan_paths:
            raw.extend(rule.check_file(scrubbed[rel]))
        raw.extend(rule.check_repo(root, scrubbed))

    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for rel in all_paths:
        allowed, defects = _suppressions(scrubbed[rel])
        raw.extend(f for f in defects if rel in scan_paths or files is None)
        for f in [f for f in raw if f.path == rel]:
            if f.rule in allowed.get(f.line, set()):
                suppressed.append(f)
        # pathless repo findings handled below
    covered = {id(f) for f in suppressed}
    findings = [f for f in raw if id(f) not in covered]
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    suppressed.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return AnalysisResult(
        findings=findings,
        suppressed=suppressed,
        files_scanned=len(scan_paths),
        rules_run=len(active_rules),
    )


def load_baseline(path: str) -> list[BaselineEntry]:
    if not os.path.exists(path):
        return []
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"{path}: expected schema {BASELINE_SCHEMA!r}, got {data.get('schema')!r}")
    return [
        BaselineEntry(
            rule=e["rule"], path=e["path"], message=e["message"], why=e.get("why", "")
        )
        for e in data.get("entries", [])
    ]


def save_baseline(path: str, entries: list[BaselineEntry]) -> None:
    data = {
        "schema": BASELINE_SCHEMA,
        "entries": [e.to_json() for e in sorted(entries, key=lambda e: e.key())],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def apply_baseline(
    findings: list[Finding], entries: list[BaselineEntry]
) -> tuple[list[Finding], list[Finding], list[BaselineEntry]]:
    """Split findings into (active, baselined) and report stale entries.

    One entry silences *every* finding with the same (rule, path,
    message) — e.g. two ``Instant`` uses in one grandfathered file.  An
    entry matching nothing is stale and must be deleted, so the
    baseline ratchets monotonically toward empty.
    """
    by_key: dict[tuple[str, str, str], BaselineEntry] = {e.key(): e for e in entries}
    hit: set[tuple[str, str, str]] = set()
    active, baselined = [], []
    for f in findings:
        if f.key() in by_key:
            baselined.append(f)
            hit.add(f.key())
        else:
            active.append(f)
    stale = [e for e in entries if e.key() not in hit]
    return active, baselined, stale


def entries_from_findings(findings: list[Finding]) -> list[BaselineEntry]:
    """Unique baseline entries covering ``findings`` (for --write-baseline)."""
    seen: dict[tuple[str, str, str], BaselineEntry] = {}
    for f in findings:
        seen.setdefault(
            f.key(),
            BaselineEntry(rule=f.rule, path=f.path, message=f.message, why="TODO: justify or fix"),
        )
    return list(seen.values())
