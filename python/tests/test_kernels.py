"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes and chain contents; every case asserts
exact equality (the kernels move data, they never compute on it, so
allclose tolerance is zero).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.copy_engine import copy_engine
from compile.kernels.gather import gather_rows
from compile.kernels.ref import copy_engine_ref, gather_rows_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _mem(rng, lines, words, dtype):
    if np.issubdtype(dtype, np.integer):
        return jnp.asarray(rng.integers(-1000, 1000, (lines, words)).astype(dtype))
    return jnp.asarray(rng.standard_normal((lines, words)).astype(dtype))


@settings(**SETTINGS)
@given(
    lines=st.integers(2, 64),
    words=st.integers(1, 32),
    ndesc=st.integers(1, 64),
    dtype=st.sampled_from([np.int32, np.float32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_copy_engine_matches_ref(lines, words, ndesc, dtype, seed):
    rng = np.random.default_rng(seed)
    mem = _mem(rng, lines, words, dtype)
    src = jnp.asarray(rng.integers(0, lines, (ndesc,), dtype=np.int32))
    dst = jnp.asarray(rng.integers(0, lines, (ndesc,), dtype=np.int32))
    out = copy_engine(mem, src, dst)
    ref = copy_engine_ref(mem, src, dst)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(**SETTINGS)
@given(
    lines=st.integers(2, 32),
    ndesc=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_copy_engine_chain_order_matters(lines, ndesc, seed):
    """Chained semantics: descriptor i observes writes of descriptors < i.

    We build a shift chain 0->1->2->... so every step reads a line the
    previous step wrote; a gather-then-scatter implementation would fail.
    """
    rng = np.random.default_rng(seed)
    mem = _mem(rng, lines, 4, np.int32)
    n = min(ndesc, lines - 1)
    # dst[i] = i+1, src[i] = i: after the chain, every line holds line 0.
    src = jnp.arange(n, dtype=jnp.int32)
    dst = jnp.arange(1, n + 1, dtype=jnp.int32)
    out = np.asarray(copy_engine(mem, src, dst))
    for i in range(n + 1):
        np.testing.assert_array_equal(out[i], np.asarray(mem)[0])


def test_copy_engine_identity_padding():
    """src == dst descriptors are no-ops (used as AOT chain padding)."""
    mem = jnp.arange(64, dtype=jnp.int32).reshape(8, 8)
    idx = jnp.asarray([3, 3, 0, 7], dtype=jnp.int32)
    out = copy_engine(mem, idx, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mem))


def test_copy_engine_empty_chain():
    mem = jnp.ones((4, 4), jnp.int32)
    out = copy_engine(mem, jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(mem))


def test_copy_engine_rejects_bad_shapes():
    mem = jnp.ones((4, 4), jnp.int32)
    with pytest.raises(ValueError):
        copy_engine(jnp.ones((4,), jnp.int32), jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32))
    with pytest.raises(ValueError):
        copy_engine(mem, jnp.zeros((2,), jnp.int32), jnp.zeros((3,), jnp.int32))


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 128),
    cols=st.integers(1, 32),
    n=st.integers(1, 64),
    dtype=st.sampled_from([np.float32, np.int32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gather_matches_ref(rows, cols, n, dtype, seed):
    rng = np.random.default_rng(seed)
    table = _mem(rng, rows, cols, dtype)
    idx = jnp.asarray(rng.integers(0, rows, (n,), dtype=np.int32))
    out = gather_rows(table, idx)
    ref = gather_rows_ref(table, idx)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_gather_duplicate_indices():
    table = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    idx = jnp.asarray([2, 2, 2, 0], jnp.int32)
    out = np.asarray(gather_rows(table, idx))
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[1], out[2])
    np.testing.assert_array_equal(out[3], np.asarray(table)[0])


def test_gather_rejects_bad_shapes():
    with pytest.raises(ValueError):
        gather_rows(jnp.ones((4,), jnp.float32), jnp.zeros((1,), jnp.int32))
    with pytest.raises(ValueError):
        gather_rows(jnp.ones((4, 4), jnp.float32), jnp.zeros((1, 1), jnp.int32))
