"""Gate logic of ``python/ci/compare_bench.py``: the bench-regression
comparisons must actually gate — scheduler-mode divergence and baseline
drift fail, bootstrap-empty baselines warn-and-pass, missing files are
hard failures (a typo'd path must not disarm the gate)."""

import json
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "ci", "compare_bench.py")


def run(args):
    return subprocess.run(
        [sys.executable, SCRIPT] + args, capture_output=True, text=True
    )


def write(path, doc):
    path.write_text(json.dumps(doc))
    return str(path)


def point_doc(schema, points):
    return {"schema": schema, "points": points}


ND_POINT = {
    "workload": "transpose",
    "row_bytes": 64,
    "rows": 64,
    "payload_bytes": 4096,
    "profile": "DDR3 (13 cycles)",
    "nd_cycles": 1000,
    "chain_cycles": 4000,
    "nd_desc_beats": 8,
    "chain_desc_beats": 256,
    "nd_ext_reuses": 1,
    "nd_writebacks": 1,
    "chain_writebacks": 64,
}


def test_nd_identical_grids_pass_with_bootstrap_baseline(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [ND_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", []))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout


def test_nd_scheduler_divergence_fails(tmp_path):
    diverged = dict(ND_POINT, nd_cycles=1001)
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [diverged]))
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", []))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_nd_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [ND_POINT]))
    drifted = dict(ND_POINT, chain_cycles=3999)
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", [drifted]))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_nd_armed_baseline_passes_on_exact_match(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [ND_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", [ND_POINT]))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "matches the checked-in baseline" in r.stdout


def test_wrong_schema_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-translation/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-translation/v1", [ND_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", []))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


def test_missing_baseline_is_a_hard_failure(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [ND_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [ND_POINT]))
    r = run(
        ["nd", "--fast", fast, "--naive", naive, "--baseline", str(tmp_path / "nope.json")]
    )
    assert r.returncode == 1
    assert "does not exist" in r.stderr


def test_empty_measured_grid_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", []))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", []))
    base = write(tmp_path / "base.json", point_doc("idmac-nd/v1", []))
    r = run(["nd", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "no points" in r.stderr


RINGS_POINT = {
    "batch": 64,
    "size": 256,
    "profile": "ideal (1 cycle)",
    "transfers": 192,
    "ring_cycles": 9000,
    "csr_cycles": 21000,
    "ring_irqs": 3,
    "csr_irqs": 192,
    "ring_doorbells": 3,
    "cq_records": 192,
    "ring_desc_beats": 768,
    "csr_desc_beats": 768,
}


def test_rings_identical_grids_pass_with_bootstrap_baseline(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-rings/v1", [RINGS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-rings/v1", [RINGS_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-rings/v1", []))
    r = run(["rings", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout


def test_rings_scheduler_divergence_fails(tmp_path):
    diverged = dict(RINGS_POINT, ring_cycles=9001)
    fast = write(tmp_path / "fast.json", point_doc("idmac-rings/v1", [RINGS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-rings/v1", [diverged]))
    base = write(tmp_path / "base.json", point_doc("idmac-rings/v1", []))
    r = run(["rings", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_rings_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-rings/v1", [RINGS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-rings/v1", [RINGS_POINT]))
    drifted = dict(RINGS_POINT, csr_cycles=20999)
    base = write(tmp_path / "base.json", point_doc("idmac-rings/v1", [drifted]))
    r = run(["rings", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_rings_rejects_nd_schema(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-nd/v1", [RINGS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-nd/v1", [RINGS_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-rings/v1", []))
    r = run(["rings", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


FAULTS_POINT = {
    "rate_ppm": 10000,
    "size": 4096,
    "profile": "DDR3 (13 cycles)",
    "transfers": 12,
    "completed": 11,
    "failed": 1,
    "retries": 9,
    "resets": 2,
    "cycles": 480000,
    "recovery_cycles": 65000,
    "goodput_bytes": 45056,
    "axi_slverrs": 14,
    "fault_halts": 2,
    "aborted_transfers": 12,
    "watchdog_trips": 0,
    "error_irqs": 14,
}


def test_faults_identical_grids_pass_with_bootstrap_baseline(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-faults/v1", [FAULTS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-faults/v1", [FAULTS_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-faults/v1", []))
    r = run(["faults", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout


def test_faults_scheduler_divergence_fails(tmp_path):
    # A fault plan that fired differently across schedulers shows up as
    # diverging counters, not just cycles — any field difference gates.
    diverged = dict(FAULTS_POINT, axi_slverrs=15)
    fast = write(tmp_path / "fast.json", point_doc("idmac-faults/v1", [FAULTS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-faults/v1", [diverged]))
    base = write(tmp_path / "base.json", point_doc("idmac-faults/v1", []))
    r = run(["faults", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_faults_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-faults/v1", [FAULTS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-faults/v1", [FAULTS_POINT]))
    drifted = dict(FAULTS_POINT, recovery_cycles=64999)
    base = write(tmp_path / "base.json", point_doc("idmac-faults/v1", [drifted]))
    r = run(["faults", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_faults_rejects_rings_schema(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-rings/v1", [FAULTS_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-rings/v1", [FAULTS_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-faults/v1", []))
    r = run(["faults", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


DRAM_POINT = {
    "workload": "gather",
    "size": 64,
    "banks": 2,
    "transfers": 512,
    "bytes": 32768,
    "cycles": 150000,
    "row_hits": 400,
    "row_misses": 120,
    "row_conflicts": 900,
    "refreshes": 48,
}


def test_dram_identical_grids_pass_with_bootstrap_baseline(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-dram/v1", [DRAM_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-dram/v1", [DRAM_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-dram/v1", []))
    r = run(["dram", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout


def test_dram_scheduler_divergence_fails(tmp_path):
    # The event-horizon scheduler skipping a refresh window or issuing a
    # command early shows up in the counters, not just cycles — any
    # field difference gates.
    diverged = dict(DRAM_POINT, row_conflicts=901)
    fast = write(tmp_path / "fast.json", point_doc("idmac-dram/v1", [DRAM_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-dram/v1", [diverged]))
    base = write(tmp_path / "base.json", point_doc("idmac-dram/v1", []))
    r = run(["dram", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_dram_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-dram/v1", [DRAM_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-dram/v1", [DRAM_POINT]))
    drifted = dict(DRAM_POINT, cycles=149999)
    base = write(tmp_path / "base.json", point_doc("idmac-dram/v1", [drifted]))
    r = run(["dram", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_dram_rejects_faults_schema(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-faults/v1", [DRAM_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-faults/v1", [DRAM_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-dram/v1", []))
    r = run(["dram", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


def quantiles(p50, p99):
    return {"p50": p50, "p99": p99, "p999": p99, "max": p99}


def arm(base):
    return {
        "launch": quantiles(base, base * 2),
        "fetch": quantiles(base + 4, base * 2 + 4),
        "data": quantiles(base + 32, base * 2 + 32),
        "writeback": quantiles(0, 8),
        "end_to_end": quantiles(base + 64, base * 2 + 64),
    }


LATENCY_POINT = {
    "batch": 8,
    "size": 64,
    "mem": "ddr3",
    "transfers": 48,
    "csr": arm(128),
    "ring": arm(64),
}


def test_latency_identical_grids_pass_with_bootstrap_baseline(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-latency/v1", []))
    r = run(["latency", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout


def test_latency_scheduler_divergence_fails(tmp_path):
    # A percentile differing between schedulers means the breakdown
    # stamps (not just end cycles) diverged — any field gates.
    diverged = dict(LATENCY_POINT, ring=arm(65))
    fast = write(tmp_path / "fast.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-latency/v1", [diverged]))
    base = write(tmp_path / "base.json", point_doc("idmac-latency/v1", []))
    r = run(["latency", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_latency_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    drifted = dict(LATENCY_POINT, csr=arm(129))
    base = write(tmp_path / "base.json", point_doc("idmac-latency/v1", [drifted]))
    r = run(["latency", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_latency_armed_baseline_passes_on_exact_match(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-latency/v1", [LATENCY_POINT]))
    r = run(["latency", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "matches the checked-in baseline" in r.stdout


def test_latency_rejects_rings_schema(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-rings/v1", [LATENCY_POINT]))
    naive = write(tmp_path / "naive.json", point_doc("idmac-rings/v1", [LATENCY_POINT]))
    base = write(tmp_path / "base.json", point_doc("idmac-latency/v1", []))
    r = run(["latency", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


def xbar_point(channels, controllers, cycles, policy="rr", granule=6):
    beats = channels * 100
    return {
        "channels": channels,
        "controllers": controllers,
        "granule_log2": granule,
        "policy": policy,
        "profile": "DDR3 (13 cycles)",
        "size": 256,
        "transfers_per_channel": 8,
        "total_cycles": cycles,
        "total_bytes": channels * 8 * 256,
        "completions": channels * 8,
        "total_beats": beats,
        "agg_util_ppm": beats * 1_000_000 // cycles,
        "per_ctrl_beats": [
            {"read_beats": beats // (2 * controllers), "write_beats": beats // (2 * controllers)}
        ]
        * controllers,
    }


# The acceptance pair: 64 channels at equal offered load, four
# controllers finishing in fewer cycles than one.
XBAR_POINTS = [
    xbar_point(64, 1, 40000),
    xbar_point(64, 4, 15000),
    xbar_point(4, 1, 9000),
    xbar_point(4, 4, 5000),
]


def test_xbar_identical_grids_pass_and_check_scaling(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", XBAR_POINTS))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", XBAR_POINTS))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 0, r.stderr
    assert "bootstrap mode" in r.stdout
    assert "beat the" in r.stdout


def test_xbar_scheduler_divergence_fails(tmp_path):
    diverged = [dict(XBAR_POINTS[0], total_cycles=40001)] + XBAR_POINTS[1:]
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", XBAR_POINTS))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", diverged))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "not deterministic" in r.stderr


def test_xbar_baseline_drift_fails(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", XBAR_POINTS))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", XBAR_POINTS))
    drifted = [dict(XBAR_POINTS[0], total_cycles=39999)] + XBAR_POINTS[1:]
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", drifted))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "drifted" in r.stderr


def test_xbar_utilization_that_fails_to_scale_gates(tmp_path):
    # Four controllers no faster than one at the max channel count:
    # the scaling invariant must fail even though the grids agree.
    flat = [
        xbar_point(64, 1, 40000),
        xbar_point(64, 4, 40000),
    ]
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", flat))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", flat))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "did not scale" in r.stderr


def test_xbar_unequal_offered_load_gates(tmp_path):
    unequal = [
        xbar_point(64, 1, 40000),
        dict(xbar_point(64, 4, 15000), total_bytes=1),
    ]
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", unequal))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", unequal))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "offered load differs" in r.stderr


def test_xbar_missing_single_controller_sibling_gates(tmp_path):
    only_multi = [xbar_point(64, 4, 15000), xbar_point(4, 1, 9000)]
    fast = write(tmp_path / "fast.json", point_doc("idmac-xbar/v1", only_multi))
    naive = write(tmp_path / "naive.json", point_doc("idmac-xbar/v1", only_multi))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "no single-controller rows" in r.stderr


def test_xbar_rejects_multichannel_schema(tmp_path):
    fast = write(tmp_path / "fast.json", point_doc("idmac-multichannel/v1", XBAR_POINTS))
    naive = write(tmp_path / "naive.json", point_doc("idmac-multichannel/v1", XBAR_POINTS))
    base = write(tmp_path / "base.json", point_doc("idmac-xbar/v1", []))
    r = run(["xbar", "--fast", fast, "--naive", naive, "--baseline", base])
    assert r.returncode == 1
    assert "unexpected schema" in r.stderr


def test_throughput_mode_gates_cycle_identity(tmp_path):
    entry = {
        "label": "fig4-grid/DDR3 (13 cycles)",
        "profile": "DDR3 (13 cycles)",
        "config": "grid(logicore+base+speculation+scaled)",
        "mode": "naive",
        "simulated_cycles": 123456,
        "wall_seconds": 1.0,
    }
    fast_entry = dict(entry, mode="fast_forward", wall_seconds=0.1)
    measured = write(
        tmp_path / "m.json",
        {"schema": "idmac-sim-throughput/v1", "entries": [entry, fast_entry]},
    )
    base = write(
        tmp_path / "b.json",
        {"schema": "idmac-sim-throughput/v1", "entries": [], "speedups": []},
    )
    r = run(["throughput", "--measured", measured, "--baseline", base, "--tolerance", "0.0"])
    assert r.returncode == 0, r.stderr
    # Diverging scheduler modes must fail even in bootstrap mode.
    bad = dict(fast_entry, simulated_cycles=123457)
    measured = write(
        tmp_path / "m2.json",
        {"schema": "idmac-sim-throughput/v1", "entries": [entry, bad]},
    )
    r = run(["throughput", "--measured", measured, "--baseline", base, "--tolerance", "0.0"])
    assert r.returncode == 1
    assert "diverged" in r.stderr


def test_repo_baselines_parse_and_use_known_schemas():
    repo = os.path.join(os.path.dirname(__file__), "..", "..")
    expected = {
        "BENCH_sim_throughput.json": "idmac-sim-throughput/v1",
        "BENCH_multichannel.json": "idmac-multichannel/v1",
        "BENCH_translation.json": "idmac-translation/v1",
        "BENCH_nd.json": "idmac-nd/v1",
        "BENCH_rings.json": "idmac-rings/v1",
        "BENCH_faults.json": "idmac-faults/v1",
        "BENCH_dram.json": "idmac-dram/v1",
        "BENCH_latency.json": "idmac-latency/v1",
        "BENCH_xbar.json": "idmac-xbar/v1",
    }
    for name, schema in expected.items():
        path = os.path.join(repo, name)
        assert os.path.exists(path), f"{name} baseline missing"
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        assert doc.get("schema") == schema, name
