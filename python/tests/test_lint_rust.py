"""CLI-level tests for python/ci/lint_rust.py: the blocking CI gate.

Includes the acceptance check that the gate runs clean on this very
tree — the same invocation CI's `lint` job performs."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
SCRIPT = os.path.join(REPO, "python", "ci", "lint_rust.py")


def run(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, SCRIPT, *args], capture_output=True, text=True, cwd=cwd
    )


def make_tree(tmp_path, files):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return str(tmp_path)


def test_real_tree_is_clean():
    # The acceptance criterion itself: zero non-baselined findings,
    # zero stale baseline entries on the current repo.
    r = run()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK:" in r.stdout
    assert "0 active finding(s)" in r.stdout
    assert "0 stale baseline entr" in r.stdout


def test_real_tree_json_report_is_parseable():
    r = run("--json", "-")
    assert r.returncode == 0, r.stderr
    report = json.loads(r.stdout)
    assert report["schema"] == "idmac-lint/v1"
    assert report["rules_run"] == 8
    assert report["active"] == []
    assert report["stale_baseline_entries"] == []
    # The sanctioned exceptions are visible, not silently dropped.
    assert any(e["path"] == "examples/perf_probe.rs" for e in report["baselined"])
    assert any(
        e["path"] == "rust/src/report/throughput.rs" for e in report["suppressed"]
    )


def test_list_rules_names_all_eight():
    r = run("--list-rules")
    assert r.returncode == 0
    for rule_id in [
        "no-wall-clock",
        "no-hash-collections",
        "no-float-in-bench-json",
        "tickable-next-event",
        "irq-map-disjoint",
        "stats-counters-documented",
        "no-ambient-rng",
        "trace-observer-only",
    ]:
        assert rule_id in r.stdout


def test_violation_fails_with_finding_line(tmp_path):
    root = make_tree(tmp_path, {"rust/src/a.rs": "use std::time::Instant;\n"})
    baseline = tmp_path / "baseline.json"
    r = run("--root", root, "--baseline", str(baseline))
    assert r.returncode == 1
    assert "FAIL: rust/src/a.rs:1: [no-wall-clock]" in r.stderr


def test_write_baseline_then_clean_then_stale(tmp_path):
    root = make_tree(tmp_path, {"rust/src/a.rs": "use std::time::Instant;\n"})
    baseline = tmp_path / "baseline.json"

    # Grandfather the finding.
    r = run("--root", root, "--baseline", str(baseline), "--write-baseline")
    assert r.returncode == 0, r.stderr
    data = json.loads(baseline.read_text())
    assert data["schema"] == "idmac-lint-baseline/v1"
    assert len(data["entries"]) == 1

    # Gate is now green: the finding is baselined.
    r = run("--root", root, "--baseline", str(baseline))
    assert r.returncode == 0, r.stderr
    assert "1 baselined" in r.stdout

    # Fix the violation but keep the entry: stale entry fails the gate.
    (tmp_path / "rust/src/a.rs").write_text("fn clean() {}\n")
    r = run("--root", root, "--baseline", str(baseline))
    assert r.returncode == 1
    assert "STALE" in r.stderr


def test_scanning_single_file_restricts_findings(tmp_path):
    root = make_tree(
        tmp_path,
        {
            "rust/src/bad.rs": "use std::time::Instant;\n",
            "rust/src/also_bad.rs": "use std::collections::HashMap;\n",
        },
    )
    baseline = tmp_path / "baseline.json"
    r = run("--root", root, "--baseline", str(baseline), "rust/src/also_bad.rs")
    assert r.returncode == 1
    assert "also_bad.rs" in r.stderr
    assert "bad.rs:1" not in r.stderr.replace("also_bad.rs:1", "")
