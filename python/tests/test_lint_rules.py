"""Per-rule fixtures: one minimal snippet that must fire each rule and
one near-miss that must not, plus suppression and baseline semantics.

Fixtures are tiny synthetic repo trees under tmp_path; ``run_analysis``
discovers files under the same roots as the real gate (rust/src,
rust/tests, rust/benches, examples)."""

from analysis import apply_baseline, run_analysis
from analysis.engine import BaselineEntry


def make_tree(tmp_path, files):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(text)
    return str(tmp_path)


def rules_fired(tmp_path, files):
    res = run_analysis(make_tree(tmp_path, files))
    return [f.rule for f in res.findings], res


# --- rule 1: no-wall-clock -------------------------------------------------

def test_wall_clock_fires_in_src(tmp_path):
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": "use std::time::Instant;\n"})
    assert "no-wall-clock" in fired


def test_wall_clock_allowed_in_benches_and_comments(tmp_path):
    fired, _ = rules_fired(
        tmp_path,
        {
            "rust/benches/b.rs": "use std::time::Instant;\n",
            "rust/src/a.rs": "// Instant is banned here\nlet x = 1;\n",
        },
    )
    assert "no-wall-clock" not in fired


# --- rule 2: no-hash-collections ------------------------------------------

def test_hash_map_fires_even_in_tests(tmp_path):
    src = "#[cfg(test)]\nmod tests {\n  fn f() { let m = std::collections::HashMap::new(); }\n}\n"
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": src})
    assert "no-hash-collections" in fired


def test_btree_map_is_fine(tmp_path):
    fired, _ = rules_fired(
        tmp_path, {"rust/src/a.rs": "let m = std::collections::BTreeMap::new();\n"}
    )
    assert "no-hash-collections" not in fired


# --- rule 3: no-float-in-bench-json ---------------------------------------

def test_float_in_report_point_struct_fires(tmp_path):
    src = "pub struct GridPoint {\n  pub cycles: u64,\n  pub util: f64,\n}\n"
    fired, res = rules_fired(tmp_path, {"rust/src/report/grid.rs": src})
    assert "no-float-in-bench-json" in fired
    assert any("struct GridPoint" in f.message for f in res.findings)


def test_float_in_json_fn_fires(tmp_path):
    src = "fn to_json() -> String { let x = 0.5; String::new() }\n"
    fired, _ = rules_fired(tmp_path, {"rust/src/report/grid.rs": src})
    assert "no-float-in-bench-json" in fired


def test_float_in_diagnostic_helper_is_fine(tmp_path):
    # Same file, but the float sits in a plain helper method, and the
    # same code outside report/ is out of scope entirely.
    src = "impl Grid { pub fn hit_rate(&self) -> f64 { self.h as f64 / 2.0 } }\n"
    fired, _ = rules_fired(
        tmp_path,
        {"rust/src/report/grid.rs": src, "rust/src/model.rs": "fn to_json() { let x = 1.5; }\n"},
    )
    assert "no-float-in-bench-json" not in fired


# --- rule 4: tickable-next-event ------------------------------------------

TICKABLE_BAD = """
struct Dev;
impl Tickable for Dev {
    fn tick(&mut self, now: Cycle) {}
}
"""

TICKABLE_GOOD = """
struct Dev;
impl Tickable for Dev {
    fn tick(&mut self, now: Cycle) {}
    fn next_event(&self) -> Option<Cycle> { None }
}
// A trait bound is not an impl:
fn run<T: Tickable>(t: &T) {}
"""


def test_tickable_without_next_event_fires(tmp_path):
    fired, res = rules_fired(tmp_path, {"rust/src/dev.rs": TICKABLE_BAD})
    assert "tickable-next-event" in fired
    assert any("`Dev`" in f.message for f in res.findings)


def test_tickable_with_next_event_and_bounds_are_fine(tmp_path):
    fired, _ = rules_fired(tmp_path, {"rust/src/dev.rs": TICKABLE_GOOD})
    assert "tickable-next-event" not in fired


# --- rule 5: irq-map-disjoint ---------------------------------------------

GUARD = "const _: () = { assert!(true) };\n"
TYPES_OK = "pub const MAX_CHANNELS: usize = 8;\n" + GUARD
PLIC_OK = "impl Plic { pub const MAX_SOURCES: u32 = 256; }\n"


def soc_consts(dmac=5, step=None):
    step = step if step is not None else "crate::axi::MAX_CHANNELS as u32"
    return (
        f"pub const DMAC_IRQ_SOURCE: u32 = {dmac};\n"
        f"pub const IOMMU_FAULT_SOURCE: u32 = DMAC_IRQ_SOURCE + {step};\n"
        f"pub const RING_IRQ_SOURCE: u32 = IOMMU_FAULT_SOURCE + {step};\n"
        f"pub const ERROR_IRQ_SOURCE: u32 = RING_IRQ_SOURCE + {step};\n"
    )


def test_disjoint_irq_map_is_clean(tmp_path):
    fired, _ = rules_fired(
        tmp_path,
        {
            "rust/src/soc/mod.rs": soc_consts() + GUARD,
            "rust/src/axi/types.rs": TYPES_OK,
            "rust/src/soc/plic.rs": PLIC_OK,
        },
    )
    assert "irq-map-disjoint" not in fired


def test_overlapping_banks_fire(tmp_path):
    # Banks step by 4 while MAX_CHANNELS is 8: every bank overlaps its
    # neighbour.
    fired, res = rules_fired(
        tmp_path,
        {
            "rust/src/soc/mod.rs": soc_consts(step="4") + GUARD,
            "rust/src/axi/types.rs": TYPES_OK,
            "rust/src/soc/plic.rs": PLIC_OK,
        },
    )
    assert "irq-map-disjoint" in fired
    assert any("overlap" in f.message for f in res.findings)


def test_plic_capacity_overflow_fires(tmp_path):
    fired, res = rules_fired(
        tmp_path,
        {
            "rust/src/soc/mod.rs": soc_consts(dmac=250) + GUARD,
            "rust/src/axi/types.rs": TYPES_OK,
            "rust/src/soc/plic.rs": PLIC_OK,
        },
    )
    assert any("MAX_SOURCES" in f.message for f in res.findings if f.rule == "irq-map-disjoint")


def test_missing_const_guard_fires(tmp_path):
    fired, res = rules_fired(
        tmp_path,
        {
            "rust/src/soc/mod.rs": soc_consts(),  # no guard block
            "rust/src/axi/types.rs": TYPES_OK,
            "rust/src/soc/plic.rs": PLIC_OK,
        },
    )
    assert any(
        "guard block" in f.message and f.path == "rust/src/soc/mod.rs"
        for f in res.findings
    )


def test_rule5_silent_without_anchor_files(tmp_path):
    fired, _ = rules_fired(tmp_path, {"rust/src/lib.rs": "fn main() {}\n"})
    assert "irq-map-disjoint" not in fired


def test_derived_max_sources_resolves_at_64_channels(tmp_path):
    # The real soc/plic.rs shape after the crossbar PR: MAX_SOURCES is
    # *derived* from the top of the IRQ map via next_power_of_two(), so
    # the map is clean at MAX_CHANNELS = 64 (top = 5 + 4*64 = 261 ≤ 512).
    plic = (
        "impl Plic { pub const MAX_SOURCES: u32 = (crate::soc::ERROR_IRQ_SOURCE"
        " + crate::axi::MAX_CHANNELS as u32).next_power_of_two(); }\n"
    )
    fired, _ = rules_fired(
        tmp_path,
        {
            "rust/src/soc/mod.rs": soc_consts() + GUARD,
            "rust/src/axi/types.rs": "pub const MAX_CHANNELS: usize = 64;\n" + GUARD,
            "rust/src/soc/plic.rs": plic,
        },
    )
    assert "irq-map-disjoint" not in fired


def test_eval_const_next_power_of_two():
    from analysis.rules import _eval_const

    env = {"A": 5, "W": 64}
    assert _eval_const("(A + 4 * W).next_power_of_two()", env) == 512
    assert _eval_const("(1).next_power_of_two()", env) == 1
    assert _eval_const("(0).next_power_of_two()", env) == 1
    assert _eval_const("(W).next_power_of_two()", env) == 64
    assert _eval_const("(W + 1).next_power_of_two()", env) == 128
    # Chained postfix calls evaluate left to right.
    assert _eval_const("(3).next_power_of_two().next_power_of_two()", env) == 4


# --- rule 6: stats-counters-documented ------------------------------------

STATS_TMPL = """
pub struct RunStats {{
    pub completions: Vec<Completion>,
    pub desc_beats: u64,
    pub end_cycle: Cycle,
}}
impl RunStats {{
    pub fn to_json(&self) -> String {{
        format!("{{}}{{}}", {json_fields})
    }}
}}
"""


def stats_tree(tmp_path, json_fields="self.desc_beats, self.end_cycle", design=True):
    files = {"rust/src/sim/stats.rs": STATS_TMPL.format(json_fields=json_fields)}
    root = make_tree(tmp_path, files)
    if design:
        (tmp_path / "DESIGN.md").write_text("counters: desc_beats, end_cycle\n")
    return root


def test_documented_counters_are_clean(tmp_path):
    res = run_analysis(stats_tree(tmp_path))
    assert "stats-counters-documented" not in [f.rule for f in res.findings]


def test_counter_missing_from_to_json_fires(tmp_path):
    res = run_analysis(stats_tree(tmp_path, json_fields="self.desc_beats"))
    msgs = [f.message for f in res.findings if f.rule == "stats-counters-documented"]
    assert any("end_cycle" in m and "to_json" in m for m in msgs)


def test_counter_missing_from_design_fires(tmp_path):
    root = stats_tree(tmp_path, design=False)
    (tmp_path / "DESIGN.md").write_text("counters: desc_beats\n")
    res = run_analysis(root)
    msgs = [f.message for f in res.findings if f.rule == "stats-counters-documented"]
    assert any("end_cycle" in m and "DESIGN.md" in m for m in msgs)


# --- rule 7: no-ambient-rng -----------------------------------------------

def test_thread_rng_and_rand_random_fire(tmp_path):
    src = "fn f() { let a = thread_rng(); let b = rand::random::<u64>(); }\n"
    _, res = rules_fired(tmp_path, {"rust/src/a.rs": src})
    assert len([f for f in res.findings if f.rule == "no-ambient-rng"]) == 2


def test_seeded_rng_and_random_like_names_are_fine(tmp_path):
    src = "fn f() { let a = SplitMix64::new(7); let random_chain = 1; }\n"
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": src})
    assert "no-ambient-rng" not in fired


# --- rule 8: trace-observer-only ------------------------------------------

TRACE_GOOD = """
fn tick(&mut self) {
    if let Some(t) = self.tracer.as_ref() {
        t.emit(now, TraceEvent::Grant);
    }
    if let Some(t) = self.sys.tracer() {
        t.emit(now, TraceEvent::PlicRaise);
    }
}
"""

TRACE_BAD = """
fn tick(&mut self) {
    self.tracer.emit(now, TraceEvent::Grant);
}
"""

TRACE_SCOPE_BAD = """
fn tick(&mut self) {
    if let Some(t) = self.tracer.as_ref() {
        t.emit(now, TraceEvent::Grant);
    }
    t.emit(now, TraceEvent::Grant);
}
"""


def test_guarded_emit_is_fine(tmp_path):
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": TRACE_GOOD})
    assert "trace-observer-only" not in fired


def test_bare_emit_fires(tmp_path):
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": TRACE_BAD})
    assert "trace-observer-only" in fired


def test_emit_outside_guard_scope_fires(tmp_path):
    _, res = rules_fired(tmp_path, {"rust/src/a.rs": TRACE_SCOPE_BAD})
    assert len([f for f in res.findings if f.rule == "trace-observer-only"]) == 1


def test_non_tracer_if_let_binding_does_not_sanction_emit(tmp_path):
    src = "fn f() { if let Some(t) = self.queue.pop() { t.emit(x); } }\n"
    fired, _ = rules_fired(tmp_path, {"rust/src/a.rs": src})
    assert "trace-observer-only" in fired


# --- suppressions ----------------------------------------------------------

def test_trailing_suppression_with_reason(tmp_path):
    src = "use std::time::Instant; // lint:allow(no-wall-clock, fixture probe)\n"
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": src}))
    assert [f.rule for f in res.findings] == []
    assert [f.rule for f in res.suppressed] == ["no-wall-clock"]


def test_own_line_suppression_covers_next_code_line(tmp_path):
    src = "// lint:allow(no-wall-clock, fixture probe)\nuse std::time::Instant;\n"
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": src}))
    assert res.findings == [] and len(res.suppressed) == 1


def test_suppression_without_reason_is_inert_and_flagged(tmp_path):
    src = "use std::time::Instant; // lint:allow(no-wall-clock)\n"
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": src}))
    fired = [f.rule for f in res.findings]
    assert "no-wall-clock" in fired  # not suppressed
    assert "suppression-needs-reason" in fired


def test_suppression_only_covers_named_rule(tmp_path):
    src = "use std::time::Instant; // lint:allow(no-hash-collections, wrong rule)\n"
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": src}))
    assert "no-wall-clock" in [f.rule for f in res.findings]


# --- baseline --------------------------------------------------------------

def test_baseline_matches_by_rule_path_message(tmp_path):
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": "use std::time::Instant;\nfn f() { let x: Instant; }\n"}))
    findings = [f for f in res.findings if f.rule == "no-wall-clock"]
    assert len(findings) == 2
    entry = BaselineEntry(
        rule=findings[0].rule, path=findings[0].path, message=findings[0].message, why="test"
    )
    active, baselined, stale = apply_baseline(findings, [entry])
    # One entry silences both same-message findings; nothing stale.
    assert active == [] and len(baselined) == 2 and stale == []


def test_stale_baseline_entry_detected(tmp_path):
    res = run_analysis(make_tree(tmp_path, {"rust/src/a.rs": "fn clean() {}\n"}))
    entry = BaselineEntry(rule="no-wall-clock", path="rust/src/a.rs", message="gone", why="old")
    active, baselined, stale = apply_baseline(res.findings, [entry])
    assert stale == [entry]
