"""Gate logic of ``python/ci/check_trace.py``: the Chrome-trace
well-formedness check must actually gate — malformed documents,
missing/typed-wrong fields, backwards timestamps and empty counter
events fail; a well-formed multi-track export passes.  Timestamps only
need to be monotone *per (pid, tid) track*, not globally."""

import json
import os
import subprocess
import sys

SCRIPT = os.path.join(os.path.dirname(__file__), "..", "ci", "check_trace.py")


def run(paths):
    return subprocess.run(
        [sys.executable, SCRIPT] + paths, capture_output=True, text=True
    )


def write(path, doc):
    path.write_text(json.dumps(doc))
    return str(path)


def instant(name, ts, tid, args=None):
    return {
        "name": name,
        "ph": "i",
        "ts": ts,
        "pid": 0,
        "tid": tid,
        "s": "t",
        "args": args or {},
    }


def counter(ts, read_beats, write_beats):
    return {
        "name": "bus_utilization",
        "ph": "C",
        "ts": ts,
        "pid": 0,
        "tid": 10,
        "args": {"read_beats": read_beats, "write_beats": write_beats},
    }


def doc(events):
    return {"traceEvents": events, "displayTimeUnit": "ns", "idmacWindowCycles": 64}


GOOD = [
    instant("csr_launch", 0, 0),
    instant("desc_fetch_start", 3, 1),
    instant("backend_active", 10, 3),
    counter(0, 4, 0),
    counter(64, 9, 9),
    instant("transfer_done", 90, 3),
]


def test_well_formed_trace_passes(tmp_path):
    p = write(tmp_path / "t.json", doc(GOOD))
    r = run([p])
    assert r.returncode == 0, r.stderr
    assert "monotone per track" in r.stdout


def test_interleaved_tracks_only_need_per_track_monotonicity(tmp_path):
    # Track 1 runs ahead of track 0; a global-order check would
    # wrongly reject this.
    events = [
        instant("desc_fetch_start", 50, 1),
        instant("csr_launch", 10, 0),
        instant("desc_fetch_done", 60, 1),
        instant("csr_launch", 20, 0),
    ]
    p = write(tmp_path / "t.json", doc(events))
    r = run([p])
    assert r.returncode == 0, r.stderr


def test_backwards_ts_on_one_track_fails(tmp_path):
    events = [instant("a", 10, 2), instant("b", 9, 2)]
    p = write(tmp_path / "t.json", doc(events))
    r = run([p])
    assert r.returncode == 1
    assert "goes backwards" in r.stderr


def test_missing_ts_fails(tmp_path):
    bad = instant("a", 1, 0)
    del bad["ts"]
    p = write(tmp_path / "t.json", doc([bad]))
    r = run([p])
    assert r.returncode == 1
    assert "ts missing" in r.stderr


def test_float_ts_fails(tmp_path):
    p = write(tmp_path / "t.json", doc([instant("a", 1.5, 0)]))
    r = run([p])
    assert r.returncode == 1
    assert "not an integer" in r.stderr


def test_empty_name_fails(tmp_path):
    p = write(tmp_path / "t.json", doc([instant("", 1, 0)]))
    r = run([p])
    assert r.returncode == 1
    assert "name missing or empty" in r.stderr


def test_counter_without_series_fails(tmp_path):
    bad = counter(0, 1, 1)
    bad["args"] = {}
    p = write(tmp_path / "t.json", doc([bad]))
    r = run([p])
    assert r.returncode == 1
    assert "without args series" in r.stderr


def test_empty_trace_fails(tmp_path):
    p = write(tmp_path / "t.json", doc([]))
    r = run([p])
    assert r.returncode == 1
    assert "traceEvents is empty" in r.stderr


def test_top_level_list_fails(tmp_path):
    # The legacy bare-array format is not what the exporter emits.
    p = write(tmp_path / "t.json", GOOD)
    r = run([p])
    assert r.returncode == 1
    assert "top level must be an object" in r.stderr


def test_invalid_json_fails(tmp_path):
    p = tmp_path / "t.json"
    p.write_text("{not json")
    r = run([str(p)])
    assert r.returncode == 1
    assert "not valid JSON" in r.stderr


def test_missing_file_fails(tmp_path):
    r = run([str(tmp_path / "nope.json")])
    assert r.returncode == 1
    assert "does not exist" in r.stderr


def test_multiple_files_all_checked(tmp_path):
    good = write(tmp_path / "good.json", doc(GOOD))
    bad = write(tmp_path / "bad.json", doc([instant("a", 5, 0), instant("b", 4, 0)]))
    r = run([good, bad])
    assert r.returncode == 1
    assert "goes backwards" in r.stderr
