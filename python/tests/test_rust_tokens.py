"""Tokenizer tests: the scrubber must never let comment/string text
masquerade as code, and must keep line numbers exact (suppressions and
findings are line-anchored)."""

from analysis.rust_tokens import match_brace, scrub


def idents(sf):
    return [t.text for t in sf.tokens if t.kind == "ident"]


def test_line_comments_are_stripped_but_collected():
    sf = scrub("f.rs", "let x = 1; // Instant HashMap\nlet y = 2;\n")
    assert "Instant" not in idents(sf)
    assert "HashMap" not in idents(sf)
    assert len(sf.comments) == 1
    assert sf.comments[0].line == 1
    assert not sf.comments[0].own_line  # trailing, code precedes it


def test_nested_block_comments():
    src = "let a = 1;\n/* outer /* Instant inner */ still comment */\nlet b = 2;\n"
    sf = scrub("f.rs", src)
    assert "Instant" not in idents(sf)
    assert "a" in idents(sf) and "b" in idents(sf)
    # The `b` binding is still reported on line 3.
    assert [t.line for t in sf.tokens if t.text == "b"] == [3]


def test_raw_strings_hide_fake_comments_and_quotes():
    src = 'let s = r#"// not a comment " Instant "#;\nlet t = 1;\n'
    sf = scrub("f.rs", src)
    assert "Instant" not in idents(sf)
    assert sf.comments == []
    assert [t.line for t in sf.tokens if t.text == "t"] == [2]


def test_byte_and_plain_strings_scrubbed_with_escapes():
    src = 'let a = b"// x";\nlet b = "quote \\" Instant";\n'
    sf = scrub("f.rs", src)
    assert "Instant" not in idents(sf)
    assert sf.comments == []


def test_backslash_newline_string_continuation_keeps_line_numbers():
    src = 'let s = "first \\\n  second";\nlet marker = 1;\n'
    sf = scrub("f.rs", src)
    assert [t.line for t in sf.tokens if t.text == "marker"] == [3]


def test_char_literal_vs_lifetime():
    src = "let c = '\"'; fn f<'a>(x: &'a str) {}\nlet q = 'x';\n"
    sf = scrub("f.rs", src)
    # The quote char literal must not open a string that eats the rest.
    assert "f" in idents(sf) and "q" in idents(sf)
    # Lifetime ident survives as a token.
    assert "a" in idents(sf)
    # Char-literal interiors are scrubbed: no `x` ident on line 2.
    assert [t.text for t in sf.tokens if t.line == 2 and t.kind == "ident"] == ["let", "q"]


def test_attribute_strings_do_not_fake_comments():
    src = '#[doc = "// lint:allow(no-wall-clock, fake)"]\nfn f() {}\n'
    sf = scrub("f.rs", src)
    assert sf.comments == []
    # The attribute's punctuation stays in the token stream.
    assert sf.tokens[0].text == "#"
    assert "doc" in idents(sf)


def test_own_line_comment_detection():
    src = "// own line\nlet x = 1; // trailing\n"
    sf = scrub("f.rs", src)
    own = [c for c in sf.comments if c.own_line]
    trailing = [c for c in sf.comments if not c.own_line]
    assert len(own) == 1 and own[0].line == 1
    assert len(trailing) == 1 and trailing[0].line == 2


def test_float_token_kind():
    sf = scrub("f.rs", "let a = 1.5; let b = 2.0e3; let c = 100; let d = 3f64;\n")
    floats = [t.text for t in sf.tokens if t.kind == "float"]
    assert "1.5" in floats and "2.0e3" in floats and "3f64" in floats
    assert "100" in [t.text for t in sf.tokens if t.kind == "num"]


def test_scrubbed_code_keeps_shape():
    src = "let a = 1; /* x */ let b = 2;\n"
    sf = scrub("f.rs", src)
    assert len(sf.code) == len(src)
    assert sf.code.count("\n") == src.count("\n")


def test_match_brace():
    sf = scrub("f.rs", "fn f() { if x { y(); } z(); }\n")
    opens = [i for i, t in enumerate(sf.tokens) if t.text == "{"]
    outer_close = match_brace(sf.tokens, opens[0])
    assert sf.tokens[outer_close].text == "}"
    assert outer_close == len(sf.tokens) - 1
