"""AOT path: every artifact lowers to parseable HLO text with the
fixed shapes the Rust runtime expects (manifest contract)."""

import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    for name, lower in aot.ARTIFACTS.items():
        text = aot.to_hlo_text(lower())
        (d / name).write_text(text)
    (d / "manifest.txt").write_text(aot.MANIFEST)
    return d


def test_all_artifacts_nonempty(out_dir):
    for name in aot.ARTIFACTS:
        text = (out_dir / name).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_copy_engine_entry_layout(out_dir):
    text = (out_dir / "copy_engine.hlo.txt").read_text()
    assert f"s32[{aot.MEM_LINES},{aot.LINE_WORDS}]" in text
    assert f"s32[{aot.CHAIN_LEN}]" in text


def test_gather_entry_layout(out_dir):
    text = (out_dir / "gather.hlo.txt").read_text()
    assert f"f32[{aot.TABLE_ROWS},{aot.TABLE_COLS}]" in text
    assert f"s32[{aot.GATHER_N}]" in text
    assert f"f32[{aot.GATHER_N},{aot.TABLE_COLS}]" in text


def test_util_model_entry_layout(out_dir):
    text = (out_dir / "util_model.hlo.txt").read_text()
    assert f"f32[{aot.UTIL_POINTS}]" in text


def test_no_custom_calls(out_dir):
    """interpret=True must lower Pallas to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for name in aot.ARTIFACTS:
        text = (out_dir / name).read_text()
        assert "custom-call" not in text, name


def test_manifest_lists_every_artifact(out_dir):
    manifest = (out_dir / "manifest.txt").read_text()
    for name in aot.ARTIFACTS:
        assert name in manifest


def test_cli_writes_artifacts(tmp_path):
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "util_model.hlo.txt"],
        cwd=repo_py, env=env, capture_output=True, text=True, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    assert (tmp_path / "util_model.hlo.txt").exists()
    assert (tmp_path / "manifest.txt").exists()
    assert not (tmp_path / "copy_engine.hlo.txt").exists()
