"""L2 analytic utilization model: invariants + paper anchor points.

These properties pin the *shape* of the curves the Fig. 4/5 benches
regenerate: ideal is Eq. 1, utilization never exceeds ideal, prefetching
helps monotonically in hit rate, and the paper's headline ratios at 64 B
come out in the right band.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model

SIZES = jnp.asarray([8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096], jnp.float32)
SETTINGS = dict(max_examples=40, deadline=None)


def test_ideal_matches_eq1():
    got = np.asarray(model.ideal_utilization(SIZES))
    want = np.asarray(SIZES) / (np.asarray(SIZES) + 32.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(**SETTINGS)
@given(
    latency=st.floats(1, 128),
    in_flight=st.integers(1, 32),
    prefetch=st.integers(0, 32),
    hit=st.floats(0, 1),
)
def test_ours_never_exceeds_ideal(latency, in_flight, prefetch, hit):
    u = np.asarray(
        model.utilization_ours(SIZES, latency, float(in_flight), float(prefetch), hit)
    )
    ideal = np.asarray(model.ideal_utilization(SIZES))
    assert (u <= ideal + 1e-6).all()
    assert (u > 0).all()


@settings(**SETTINGS)
@given(latency=st.floats(1, 128))
def test_logicore_never_exceeds_ideal(latency):
    u = np.asarray(model.utilization_logicore(SIZES, latency))
    ideal = np.asarray(model.ideal_utilization(SIZES))
    assert (u <= ideal + 1e-6).all()
    assert (u > 0).all()


@settings(**SETTINGS)
@given(latency=st.floats(1, 128), in_flight=st.integers(1, 32), prefetch=st.integers(1, 32))
def test_hit_rate_monotone(latency, in_flight, prefetch):
    lo = np.asarray(model.utilization_ours(SIZES, latency, float(in_flight), float(prefetch), 0.0))
    hi = np.asarray(model.utilization_ours(SIZES, latency, float(in_flight), float(prefetch), 1.0))
    assert (hi >= lo - 1e-6).all()


@settings(**SETTINGS)
@given(latency=st.floats(1, 128), in_flight=st.integers(1, 32))
def test_prefetch_beats_base_at_full_hit_rate(latency, in_flight):
    base = np.asarray(model.utilization_ours(SIZES, latency, float(in_flight), 0.0, 1.0))
    spec = np.asarray(model.utilization_ours(SIZES, latency, float(in_flight), float(in_flight), 1.0))
    assert (spec >= base - 1e-6).all()


@settings(**SETTINGS)
@given(latency=st.floats(1, 128))
def test_ours_base_beats_logicore(latency):
    ours = np.asarray(model.utilization_ours(SIZES, latency, 4.0, 0.0, 1.0))
    lc = np.asarray(model.utilization_logicore(SIZES, latency))
    assert (ours >= lc - 1e-6).all()


def _at64(u):
    return float(np.asarray(u)[np.asarray(SIZES) == 64.0][0])


def test_paper_anchor_ideal_memory_64B():
    """Fig. 4a: base hits ideal in ideal memory; ~2.5x over LogiCORE @64 B."""
    base = _at64(model.utilization_ours(SIZES, 1.0, 4.0, 0.0, 1.0))
    ideal = _at64(model.ideal_utilization(SIZES))
    lc = _at64(model.utilization_logicore(SIZES, 1.0))
    assert abs(base - ideal) < 1e-6
    assert 2.0 < base / lc < 3.0  # paper: 2.5x


def test_paper_anchor_ddr3_crossovers():
    """Fig. 4b: ideal from 256 B without and 64 B with prefetching."""
    sizes = np.asarray(SIZES)
    ideal = np.asarray(model.ideal_utilization(SIZES))
    base = np.asarray(model.utilization_ours(SIZES, 13.0, 4.0, 0.0, 1.0))
    spec = np.asarray(model.utilization_ours(SIZES, 13.0, 4.0, 4.0, 1.0))
    base_cross = sizes[np.isclose(base, ideal, rtol=1e-5)].min()
    spec_cross = sizes[np.isclose(spec, ideal, rtol=1e-5)].min()
    assert base_cross == 256.0
    assert spec_cross <= 64.0


def test_paper_anchor_ddr3_64B_ratios():
    """Fig. 4b @64 B: paper reports 1.7x (base) and 3.9x (speculation)."""
    lc = _at64(model.utilization_logicore(SIZES, 13.0))
    base = _at64(model.utilization_ours(SIZES, 13.0, 4.0, 0.0, 1.0))
    spec = _at64(model.utilization_ours(SIZES, 13.0, 4.0, 4.0, 1.0))
    assert 1.4 < base / lc < 2.1  # paper: 1.7x
    assert 3.0 < spec / lc < 5.0  # paper: 3.9x (model lands ~4.5x)


def test_paper_anchor_table4_rf_rb():
    """Table IV rf-rb: ours 8/32/206; LogiCORE 22/48/222 (±2 cycles)."""
    for lat, want in [(1.0, 8.0), (13.0, 32.0), (100.0, 206.0)]:
        assert float(model.rf_rb_ours(lat)) == want
    for lat, want in [(1.0, 22.0), (13.0, 48.0), (100.0, 222.0)]:
        assert abs(float(model.rf_rb_logicore(lat)) - want) <= 2.0


def test_utilization_tuple_entry_point():
    ideal, ours, lc = model.utilization(SIZES, 13.0, 4.0, 4.0, 1.0)
    assert ideal.shape == ours.shape == lc.shape == SIZES.shape
