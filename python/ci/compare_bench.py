#!/usr/bin/env python3
"""CI bench-regression gate over the simulator's JSON reports.

Two modes, both gating on *simulated cycle counts* only — wall-clock
fields are ignored by design, so runner speed cannot flake the build:

``throughput``
    Validates ``BENCH_sim_throughput.json``-shaped files: (1) inside
    the measured file, the ``naive`` and ``fast_forward`` modes of each
    (label, profile, config) must report identical ``simulated_cycles``
    (the schedulers are cycle-identical by construction); (2) every
    entry of the checked-in baseline must be reproduced within
    ``--tolerance`` relative drift.

``multichannel``
    Validates ``BENCH_multichannel.json``-shaped files: the grids
    emitted with and without ``--naive`` must be identical, and must
    match the checked-in baseline exactly.

``translation``
    Validates ``BENCH_translation.json``-shaped files with the same
    protocol as ``multichannel`` (scheduler-mode identity + exact
    baseline match) against the ``idmac-translation/v1`` schema.

``nd``
    Validates ``BENCH_nd.json``-shaped files (the ND-native vs
    chain-expanded grid) with the same protocol against the
    ``idmac-nd/v1`` schema.

``rings``
    Validates ``BENCH_rings.json``-shaped files (the CSR-launch vs
    ring-doorbell grid) with the same protocol against the
    ``idmac-rings/v1`` schema.

``faults``
    Validates ``BENCH_faults.json``-shaped files (the fault-injection
    goodput/recovery grid) with the same protocol against the
    ``idmac-faults/v1`` schema.  The fault plan is a pure function of
    its seed, so the grid is exact-diffed like every other point grid.

``dram``
    Validates ``BENCH_dram.json``-shaped files (the row-buffer
    locality grid on the banked DRAM timing backend) with the same
    protocol against the ``idmac-dram/v1`` schema.

``latency``
    Validates ``BENCH_latency.json``-shaped files (the per-phase
    latency-percentile grid, CSR burst vs ring doorbell) with the same
    protocol against the ``idmac-latency/v1`` schema.  Percentiles are
    integer cycle counts over log2 buckets, so the grid is exact-diffed
    like every other point grid.

``xbar``
    Validates ``BENCH_xbar.json``-shaped files (the crossbar
    interconnect scaling grid) with the point-grid protocol against
    the ``idmac-xbar/v1`` schema, plus the *scaling invariant*: for
    every (channels, policy, granule) at maximum channel count, the
    multi-controller rows must carry the same offered load
    (``total_bytes``/``total_beats``) as the single-controller row and
    report strictly higher ``agg_util_ppm`` — adding interleaved
    memory controllers must actually raise aggregate bus utilization.

A baseline file with no entries/points is *bootstrap mode*: the gate
warns and passes, and the measured file (uploaded as a CI artifact) is
what should be committed as the new baseline.
"""

import argparse
import json
import sys


def fail(msg: str) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path: str) -> dict:
    """Load a JSON report.  A missing file is always a hard failure:
    bootstrap mode is only for a *present* baseline with an empty
    entries/points array — a typo'd or deleted baseline must not
    silently disarm the gate."""
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} does not exist")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


def strip_wallclock(entry: dict) -> dict:
    """Project a throughput entry onto its deterministic fields."""
    return {
        k: entry[k]
        for k in ("label", "profile", "config", "mode", "simulated_cycles")
        if k in entry
    }


def check_throughput(measured_path: str, baseline_path: str, tolerance: float) -> None:
    measured = load(measured_path)
    if not measured:
        fail(f"measured file {measured_path} missing or empty")
    if measured.get("schema") != "idmac-sim-throughput/v1":
        fail(f"unexpected schema in {measured_path}: {measured.get('schema')}")
    entries = measured.get("entries", [])
    if not entries:
        fail(f"{measured_path} has no entries")

    # (1) cycle-identity between the two scheduler modes.
    by_key = {}
    for e in entries:
        by_key.setdefault((e["label"], e["profile"], e["config"]), {})[e["mode"]] = e
    for key, modes in by_key.items():
        if {"naive", "fast_forward"} <= set(modes):
            n = modes["naive"]["simulated_cycles"]
            f = modes["fast_forward"]["simulated_cycles"]
            if n != f:
                fail(
                    f"scheduler modes diverged for {key}: "
                    f"naive={n} fast_forward={f} simulated cycles"
                )
    print(f"OK: {len(by_key)} workload(s) cycle-identical across scheduler modes")

    # (2) baseline drift.
    baseline = load(baseline_path)
    base_entries = baseline.get("entries", [])
    if not base_entries:
        print(
            f"WARN: baseline {baseline_path} is empty (bootstrap mode) — "
            f"commit the uploaded artifact to arm the gate"
        )
        return
    measured_by_key = {
        (e["label"], e["profile"], e["config"], e["mode"]): e["simulated_cycles"]
        for e in entries
    }
    checked = 0
    for b in base_entries:
        key = (b["label"], b["profile"], b["config"], b["mode"])
        if key not in measured_by_key:
            # The baseline may cover a wider grid than the CI run
            # (e.g. all profiles vs the small DDR3-only gate grid).
            continue
        want = b["simulated_cycles"]
        got = measured_by_key[key]
        drift = abs(got - want) / max(want, 1)
        if drift > tolerance:
            fail(
                f"cycle-count drift for {key}: baseline {want}, measured {got} "
                f"({drift:.4%} > {tolerance:.4%})"
            )
        checked += 1
    if checked == 0:
        fail("baseline and measured files share no comparable entries")
    print(f"OK: {checked} baseline entrie(s) within {tolerance:.2%} cycle drift")


def check_point_grid(
    fast_path: str, naive_path: str, baseline_path: str, schema: str, what: str
) -> None:
    """Shared gate for point-grid reports (multichannel, translation):
    the fast and naive grids must be identical and must match the
    checked-in baseline exactly (bootstrap-empty baselines warn)."""
    fast = load(fast_path)
    naive = load(naive_path)
    for name, doc in ((fast_path, fast), (naive_path, naive)):
        if not doc:
            fail(f"{name} missing or empty")
        if doc.get("schema") != schema:
            fail(f"unexpected schema in {name}: {doc.get('schema')}")
        if not doc.get("points"):
            fail(f"{name} has no points")
    if fast != naive:
        fail(
            f"{fast_path} and {naive_path} differ — the {what} grid is "
            f"not deterministic across scheduler modes"
        )
    print(f"OK: {len(fast['points'])} {what} point(s) identical across scheduler modes")

    baseline = load(baseline_path)
    base_points = baseline.get("points", [])
    if not base_points:
        print(
            f"WARN: baseline {baseline_path} is empty (bootstrap mode) — "
            f"commit the uploaded artifact to arm the gate"
        )
        return
    if base_points != fast["points"]:
        fail(f"{what} grid drifted from the checked-in {baseline_path}")
    print(f"OK: {what} grid matches the checked-in baseline")


def check_multichannel(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(
        fast_path, naive_path, baseline_path, "idmac-multichannel/v1", "contention"
    )


def check_translation(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(
        fast_path, naive_path, baseline_path, "idmac-translation/v1", "translation"
    )


def check_nd(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-nd/v1", "nd")


def check_rings(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-rings/v1", "rings")


def check_faults(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-faults/v1", "faults")


def check_dram(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-dram/v1", "dram")


def check_latency(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-latency/v1", "latency")


def check_xbar_scaling(points: list) -> None:
    """The crossbar acceptance invariant, checked on the measured grid
    (independent of the baseline, so it also gates bootstrap runs):
    at the maximum swept channel count, every multi-controller row must
    move the same offered load as its single-controller sibling and
    report strictly higher aggregate utilization."""
    max_ch = max(p["channels"] for p in points)
    singles = {
        (p["policy"], p["granule_log2"]): p
        for p in points
        if p["channels"] == max_ch and p["controllers"] == 1
    }
    if not singles:
        fail(f"no single-controller rows at {max_ch} channels to compare against")
    checked = 0
    for p in points:
        if p["channels"] != max_ch or p["controllers"] == 1:
            continue
        base = singles.get((p["policy"], p["granule_log2"]))
        if base is None:
            fail(
                f"no 1-controller sibling for {max_ch}ch/"
                f"{p['policy']}/g{p['granule_log2']}"
            )
        key = f"{max_ch}ch/{p['controllers']}ctrl/{p['policy']}/g{p['granule_log2']}"
        if p["total_bytes"] != base["total_bytes"]:
            fail(f"offered load differs from the 1-controller row at {key}")
        if p["total_beats"] != base["total_beats"]:
            fail(f"beat count not conserved vs the 1-controller row at {key}")
        if p["agg_util_ppm"] <= base["agg_util_ppm"]:
            fail(
                f"aggregate utilization did not scale at {key}: "
                f"{p['agg_util_ppm']} ppm <= {base['agg_util_ppm']} ppm"
            )
        checked += 1
    if checked == 0:
        fail(f"no multi-controller rows at {max_ch} channels")
    print(
        f"OK: {checked} multi-controller row(s) at {max_ch} channels beat the "
        f"single-controller utilization at equal offered load"
    )


def check_xbar(fast_path: str, naive_path: str, baseline_path: str) -> None:
    check_point_grid(fast_path, naive_path, baseline_path, "idmac-xbar/v1", "xbar")
    check_xbar_scaling(load(fast_path)["points"])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)

    t = sub.add_parser("throughput")
    t.add_argument("--measured", required=True)
    t.add_argument("--baseline", required=True)
    t.add_argument("--tolerance", type=float, default=0.0)

    m = sub.add_parser("multichannel")
    m.add_argument("--fast", required=True)
    m.add_argument("--naive", required=True)
    m.add_argument("--baseline", required=True)

    tr = sub.add_parser("translation")
    tr.add_argument("--fast", required=True)
    tr.add_argument("--naive", required=True)
    tr.add_argument("--baseline", required=True)

    nd = sub.add_parser("nd")
    nd.add_argument("--fast", required=True)
    nd.add_argument("--naive", required=True)
    nd.add_argument("--baseline", required=True)

    rg = sub.add_parser("rings")
    rg.add_argument("--fast", required=True)
    rg.add_argument("--naive", required=True)
    rg.add_argument("--baseline", required=True)

    fl = sub.add_parser("faults")
    fl.add_argument("--fast", required=True)
    fl.add_argument("--naive", required=True)
    fl.add_argument("--baseline", required=True)

    dr = sub.add_parser("dram")
    dr.add_argument("--fast", required=True)
    dr.add_argument("--naive", required=True)
    dr.add_argument("--baseline", required=True)

    la = sub.add_parser("latency")
    la.add_argument("--fast", required=True)
    la.add_argument("--naive", required=True)
    la.add_argument("--baseline", required=True)

    xb = sub.add_parser("xbar")
    xb.add_argument("--fast", required=True)
    xb.add_argument("--naive", required=True)
    xb.add_argument("--baseline", required=True)

    args = ap.parse_args()
    if args.mode == "throughput":
        check_throughput(args.measured, args.baseline, args.tolerance)
    elif args.mode == "multichannel":
        check_multichannel(args.fast, args.naive, args.baseline)
    elif args.mode == "translation":
        check_translation(args.fast, args.naive, args.baseline)
    elif args.mode == "nd":
        check_nd(args.fast, args.naive, args.baseline)
    elif args.mode == "rings":
        check_rings(args.fast, args.naive, args.baseline)
    elif args.mode == "faults":
        check_faults(args.fast, args.naive, args.baseline)
    elif args.mode == "dram":
        check_dram(args.fast, args.naive, args.baseline)
    elif args.mode == "latency":
        check_latency(args.fast, args.naive, args.baseline)
    else:
        check_xbar(args.fast, args.naive, args.baseline)


if __name__ == "__main__":
    main()
