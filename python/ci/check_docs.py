#!/usr/bin/env python3
"""Docs gate: resolve local markdown links and pin required sections.

* Every ``[text](target)`` link in the repo's markdown files whose
  target is a local path (no URL scheme) must resolve to an existing
  file, relative to the file containing the link (anchors stripped).
* DESIGN.md and EXPERIMENTS.md must keep the sections other files and
  the CI bench gate point at.
"""

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

MARKDOWN_FILES = [
    "README.md",
    "DESIGN.md",
    "EXPERIMENTS.md",
    "ROADMAP.md",
    "PAPER.md",
    "PAPERS.md",
    "CHANGES.md",
]

REQUIRED_SECTIONS = {
    "README.md": [
        "Quickstart",
        "translate",
        "faults",
        "dram",
        "latency",
        "trace",
        "xbar",
        "--stats-json",
        "bench-regression gate",
        "lint_rust.py",
    ],
    "DESIGN.md": [
        "Multi-channel",
        "event horizon",
        "Experiment index",
        "Virtual memory & IOMMU",
        "Rings",
        "Error model and recovery",
        "DRAM backend",
        "Trace & telemetry",
        "Static analysis & determinism lints",
        "Crossbar",
    ],
    "EXPERIMENTS.md": [
        "Contention",
        "Translation",
        "Rings",
        "Faults",
        "DRAM",
        "Latency",
        "Crossbar",
        "BENCH_multichannel.json",
        "BENCH_sim_throughput.json",
        "BENCH_translation.json",
        "BENCH_rings.json",
        "BENCH_faults.json",
        "BENCH_dram.json",
        "BENCH_latency.json",
        "BENCH_xbar.json",
    ],
}

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def main() -> int:
    errors = []
    for name in MARKDOWN_FILES:
        path = os.path.join(REPO, name)
        if not os.path.exists(path):
            errors.append(f"{name}: file missing")
            continue
        with open(path, "r", encoding="utf-8") as f:
            text = f.read()
        for target in LINK.findall(text):
            if "://" in target or target.startswith("mailto:"):
                continue
            local = target.split("#", 1)[0]
            if not local:
                continue  # pure anchor
            resolved = os.path.normpath(os.path.join(os.path.dirname(path), local))
            if not os.path.exists(resolved):
                errors.append(f"{name}: broken link -> {target}")
        for needle in REQUIRED_SECTIONS.get(name, []):
            if needle not in text:
                errors.append(f"{name}: required section/reference `{needle}` missing")
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"OK: {len(MARKDOWN_FILES)} markdown files, links and required sections intact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
