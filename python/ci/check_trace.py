#!/usr/bin/env python3
"""CI well-formedness gate for ``idmac trace`` Chrome-trace exports.

Validates the JSON Array Format that ``chrome://tracing`` / Perfetto
consume (and that ``sim::trace::chrome_trace_json`` promises to emit):

* the document is one object with a ``traceEvents`` list;
* every event has a non-empty string ``name``, a one-character phase
  ``ph``, integer ``pid``/``tid``, and a non-negative integer ``ts``;
* on every ``(pid, tid)`` track, ``ts`` is monotone non-decreasing —
  the exporter sorts by cycle, so an out-of-order timestamp means the
  export (not the simulation) regressed;
* counter events (``ph == "C"``) carry an ``args`` object of numeric
  series (the bus-utilization track).

Usage: ``python python/ci/check_trace.py TRACE.json [TRACE2.json ...]``
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path: str) -> None:
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except FileNotFoundError:
        fail(f"{path} does not exist")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail(f"{path}: top level must be an object, got {type(doc).__name__}")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail(f"{path}: traceEvents missing or not a list")
    if not events:
        fail(f"{path}: traceEvents is empty")

    last_ts = {}  # (pid, tid) -> last seen ts
    tracks = set()
    counters = 0
    for i, e in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(e, dict):
            fail(f"{where} is not an object")
        name = e.get("name")
        if not isinstance(name, str) or not name:
            fail(f"{where}: name missing or empty")
        ph = e.get("ph")
        if not isinstance(ph, str) or len(ph) != 1:
            fail(f"{where} ({name}): ph missing or not a single character")
        for key in ("ts", "pid", "tid"):
            v = e.get(key)
            # bool is an int subclass in Python; reject it explicitly.
            if not isinstance(v, int) or isinstance(v, bool):
                fail(f"{where} ({name}): {key} missing or not an integer")
        if e["ts"] < 0:
            fail(f"{where} ({name}): negative ts {e['ts']}")
        track = (e["pid"], e["tid"])
        tracks.add(track)
        if e["ts"] < last_ts.get(track, 0):
            fail(
                f"{where} ({name}): ts {e['ts']} goes backwards on track "
                f"pid={track[0]} tid={track[1]} (last {last_ts[track]})"
            )
        last_ts[track] = e["ts"]
        if ph == "C":
            counters += 1
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                fail(f"{where} ({name}): counter event without args series")
            for k, v in args.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    fail(f"{where} ({name}): counter series {k} is not numeric")

    print(
        f"OK: {path}: {len(events)} event(s) on {len(tracks)} track(s), "
        f"{counters} counter sample(s), timestamps monotone per track"
    )


def main() -> None:
    if len(sys.argv) < 2:
        fail("usage: check_trace.py TRACE.json [TRACE2.json ...]")
    for path in sys.argv[1:]:
        check_trace(path)


if __name__ == "__main__":
    main()
