#!/usr/bin/env python3
"""Determinism lint gate over the Rust sources (DESIGN.md §14).

Runs the ``python/analysis`` rule engine and fails on

* any finding that is neither inline-suppressed
  (``// lint:allow(rule-id, reason)``) nor grandfathered in
  ``python/analysis/baseline.json``, and
* any baseline entry that no longer matches a finding (stale entries
  must be deleted, so the baseline only ever shrinks).

Usage::

    python python/ci/lint_rust.py                 # gate the whole repo
    python python/ci/lint_rust.py rust/src/axi/arbiter.rs   # one file
    python python/ci/lint_rust.py --json -        # machine-readable report
    python python/ci/lint_rust.py --write-baseline  # grandfather current findings
    python python/ci/lint_rust.py --list-rules    # show the rule table
"""

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(REPO, "python"))

from analysis import (  # noqa: E402
    ALL_RULES,
    apply_baseline,
    load_baseline,
    run_analysis,
)
from analysis.engine import entries_from_findings, save_baseline  # noqa: E402

DEFAULT_BASELINE = os.path.join("python", "analysis", "baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="repo-relative .rs files to scan (default: all)")
    ap.add_argument("--root", default=REPO, help="repo root to scan (default: this repo)")
    ap.add_argument("--baseline", default=None, help=f"baseline path (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--json", metavar="PATH", help="write idmac-lint/v1 JSON report (- for stdout)")
    ap.add_argument("--write-baseline", action="store_true", help="grandfather all current findings and exit 0")
    ap.add_argument("--list-rules", action="store_true", help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for n, rule in enumerate(ALL_RULES, start=1):
            print(f"{n}. {rule.rule_id}: {rule.summary}")
        return 0

    root = os.path.abspath(args.root)
    baseline_path = args.baseline or os.path.join(root, DEFAULT_BASELINE)
    files = [f.replace(os.sep, "/") for f in args.files] or None

    result = run_analysis(root, files=files)
    if args.write_baseline:
        save_baseline(baseline_path, entries_from_findings(result.findings))
        print(f"wrote {len(entries_from_findings(result.findings))} baseline entries to {baseline_path}")
        print("fill in each entry's `why` — unexplained grandfathering defeats the gate")
        return 0

    entries = load_baseline(baseline_path)
    # Scanning a subset must not flag whole-repo baseline entries as
    # stale: restrict staleness to the scanned paths.
    if files is not None:
        entries_in_scope = [e for e in entries if e.path in files]
    else:
        entries_in_scope = entries
    active, baselined, stale = apply_baseline(result.findings, entries_in_scope)

    report = {
        "schema": "idmac-lint/v1",
        "files_scanned": result.files_scanned,
        "rules_run": result.rules_run,
        "active": [f.to_json() for f in active],
        "baselined": [f.to_json() for f in baselined],
        "suppressed": [f.to_json() for f in result.suppressed],
        "stale_baseline_entries": [e.to_json() for e in stale],
    }
    if args.json:
        text = json.dumps(report, indent=2)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(text + "\n")

    for f in active:
        print(f"FAIL: {f.render()}", file=sys.stderr)
    for e in stale:
        print(
            f"STALE: baseline entry [{e.rule}] {e.path} no longer matches any finding — delete it",
            file=sys.stderr,
        )
    verdict = (
        f"{result.files_scanned} files, {result.rules_run} rules: "
        f"{len(active)} active finding(s), {len(baselined)} baselined, "
        f"{len(result.suppressed)} suppressed, {len(stale)} stale baseline entr(y/ies)"
    )
    if active or stale:
        print(f"FAIL: {verdict}", file=sys.stderr)
        return 1
    # Keep stdout pure JSON when the report is streamed there.
    print(f"OK: {verdict}", file=sys.stderr if args.json == "-" else sys.stdout)
    return 0


if __name__ == "__main__":
    sys.exit(main())
